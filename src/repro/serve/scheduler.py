"""Admission / eviction policy for the continuous-batching engine.

FCFS with head-of-line blocking: the waiting queue is kept in arrival order
and admission always considers the *head* first, stopping at the first
request that does not fit (no bypass).  That is the no-starvation guarantee —
a large old request can never be overtaken indefinitely by small young ones.

Eviction is youngest-first (max arrival ticket): when the page pool cannot
grow a running request's cache, the most recently admitted request is
preempted — its pages are freed and it re-enters the waiting queue in
arrival order, so it is also the first to come back.  Preempting the
youngest bounds wasted work and, combined with FCFS admission, guarantees
the oldest request always makes progress.
"""
from __future__ import annotations

import bisect
from typing import Callable, Optional

from repro.serve.request import RequestState, ServeRequest


class Scheduler:
    def __init__(self, max_slots: int):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.max_slots = max_slots
        self.waiting: list[ServeRequest] = []  # kept sorted by arrival
        self.running: list[ServeRequest] = []

    @property
    def free_slots(self) -> int:
        return self.max_slots - len(self.running)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------- admission
    def submit(self, req: ServeRequest) -> None:
        """Enqueue a new or preempted request, keeping arrival order."""
        req.state = RequestState.WAITING
        bisect.insort(self.waiting, req, key=lambda r: r.arrival)

    def admit(self, fits: Callable[[ServeRequest], bool]) -> list[ServeRequest]:
        """Move waiting requests into the running set, FCFS.

        ``fits(req)`` answers whether the KV pool can hold req's prefill.
        Stops at the first request that doesn't fit (head-of-line blocking —
        the no-starvation invariant), or when slots run out.
        """
        admitted: list[ServeRequest] = []
        while self.waiting and self.free_slots > 0 and fits(self.waiting[0]):
            req = self.waiting.pop(0)
            req.state = RequestState.RUNNING
            self.running.append(req)
            admitted.append(req)
        return admitted

    # -------------------------------------------------------------- eviction
    def pick_victim(self, kv_bits: Optional[int] = None) -> Optional[ServeRequest]:
        """Youngest running request (optionally restricted to one KV pool)."""
        pool = [
            r for r in self.running if kv_bits is None or r.kv_bits == kv_bits
        ]
        return max(pool, key=lambda r: r.arrival) if pool else None

    def preempt(self, req: ServeRequest) -> None:
        """Remove req from the running set and requeue it (recompute-style)."""
        self.running.remove(req)
        req.preemptions += 1
        req.cache_len = 0
        self.submit(req)

    def finish(self, req: ServeRequest) -> None:
        self.running.remove(req)
        req.state = RequestState.FINISHED
