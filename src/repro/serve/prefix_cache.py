"""Block-hash prefix cache over the paged KV pool.

SPEED's throughput argument is *data reuse* — don't re-fetch (here: don't
recompute) operands you already hold.  Applied at the request level: a page
that holds the K/V of a full ``page_size``-token block is addressable by the
**hash chain** of the tokens that produced it, so any later request whose
prompt starts with the same token blocks adopts the pages read-only instead
of re-prefilling them.

    h_0 = H(salt)                 salt = (w_bits,) — K/V values depend on the
    h_i = H(h_{i-1} || block_i)   weight precision that computed them, so W4
                                  and W8 requests never share pages even in
                                  the same kv_bits pool.  kv_bits isolation
                                  is structural: one PrefixCache per pool.

Only *full* blocks are cacheable (a partial block's page will still be
written).  Lifecycle of a cached page:

  * **registered** while its owner still runs — other requests incref and
    share it immediately (the pool's refcount keeps it alive).
  * **retained** when the last reference drops: the pool's release hook hands
    it here instead of the free list, and it joins the LRU ring, still
    serving hits.
  * **evicted** when the pool runs dry: the reclaim hook pops the
    least-recently-used retained pages back to the free list and deletes
    their hash entries.  Referenced pages are never evicted.

``match`` returns the longest *contiguous* cached chain — a gap (evicted
block) ends the usable prefix even if later blocks survive, because block i's
K/V cannot be adopted without blocks < i materialized.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.serve.kv_cache import PagedKVCache


def block_hashes(tokens: np.ndarray, block: int, salt: tuple = ()) -> list[bytes]:
    """Hash chain over the full ``block``-token blocks of ``tokens``."""
    tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
    h = hashlib.sha256(repr(salt).encode()).digest()
    out = []
    for i in range(len(tokens) // block):
        h = hashlib.sha256(h + tokens[i * block : (i + 1) * block].tobytes()).digest()
        out.append(h)
    return out


@dataclass
class PrefixCacheStats:
    """Accounted by the engine at *successful admission* (both sides of the
    ratio), so retries of a blocked request and matched-but-degraded chains
    skew neither numerator nor denominator."""

    lookups: int = 0  # admissions that consulted the cache
    lookup_tokens: int = 0  # full-block tokens those admissions asked for
    hit_tokens: int = 0  # tokens adopted into a table
    registered_blocks: int = 0
    evictions: int = 0
    forks: int = 0  # copy-on-write page forks at divergence points

    @property
    def hit_rate(self) -> float:
        return self.hit_tokens / max(self.lookup_tokens, 1)


class PrefixCache:
    """One per ``PagedKVCache`` pool; installs itself as the pool's
    release/reclaim layer."""

    def __init__(self, pool: PagedKVCache):
        self.pool = pool
        self.block = pool.page_size
        self._entries: dict[bytes, int] = {}  # block hash -> page id
        self._by_page: dict[int, bytes] = {}  # inverse (for hooks)
        self._lru: OrderedDict[bytes, None] = OrderedDict()  # retained, LRU->MRU
        self.stats = PrefixCacheStats()
        pool.release_hook = self._on_release
        pool.reclaim_hook = self._reclaim
        pool.reclaimable_fn = lambda: len(self._lru)

    # ----------------------------------------------------------------- hooks
    def _on_release(self, page: int) -> bool:
        """Pool hook: last reference to ``page`` dropped.  Retain it (True)
        if it still backs a hash entry, else let it return to the free list."""
        h = self._by_page.get(page)
        if h is None:
            return False
        self._lru[h] = None
        self._lru.move_to_end(h)
        return True

    def _reclaim(self, n: int) -> list[int]:
        """Pool hook: evict up to ``n`` least-recently-used retained pages."""
        pages = []
        while self._lru and len(pages) < n:
            h, _ = self._lru.popitem(last=False)
            page = self._entries[h]
            if self.pool.refcount(page) > 0:
                # revived by an adopter that hasn't called acquire_note yet:
                # live pages are never evicted, just un-retained
                continue
            del self._entries[h]
            del self._by_page[page]
            pages.append(page)
            self.stats.evictions += 1
        return pages

    # ----------------------------------------------------------------- reuse
    def match(self, hashes: list[bytes]) -> list[int]:
        """Pages backing the longest contiguous cached block chain.  Pure
        lookup, no stats — the caller increfs via
        ``pool.allocate(prefix_pages=...)`` (which revives retained pages)
        before anything can evict them, and accounts ``stats`` for what it
        actually adopts at admission."""
        pages = []
        for h in hashes:
            page = self._entries.get(h)
            if page is None:
                break
            pages.append(page)
        return pages

    def acquire_note(self, pages: list[int]) -> None:
        """Un-retain pages the caller just incref'd (they are live again)."""
        for p in pages:
            h = self._by_page.get(p)
            if h is not None:
                self._lru.pop(h, None)

    def register(self, hashes: list[bytes], pages: list[int]) -> None:
        """Map each full block's hash to the (live, refcounted) page holding
        its K/V.  First writer wins: an already-registered hash keeps its
        existing page, so concurrent same-prefix requests converge on one
        physical copy as their tables drop references."""
        for h, p in zip(hashes, pages):
            if h in self._entries or p in self._by_page:
                continue
            self._entries[h] = p
            self._by_page[p] = h
            self.stats.registered_blocks += 1

    def forget_pages(self, pages: list[int]) -> None:
        """Drop the hash entries (and any LRU retention) for ``pages`` whose
        *content* is no longer the registered blocks' K/V — speculative
        rollback truncates tail pages that verify may have overwritten with
        rejected-token K/V, so they must stop serving prefix hits before the
        pool reclaims them.  Unregistered pages are ignored."""
        for p in pages:
            h = self._by_page.pop(p, None)
            if h is None:
                continue
            del self._entries[h]
            retained = h in self._lru
            if retained:
                del self._lru[h]
            # a retained page (refcount 0) was held out of the free list by
            # the release hook; with its entry gone nothing will ever free
            # it, so hand it back to the pool now
            if retained and self.pool.refcount(p) == 0:
                self.pool.release_retained(p)

    # ----------------------------------------------------------------- admin
    @property
    def num_entries(self) -> int:
        return len(self._entries)

    @property
    def num_retained(self) -> int:
        return len(self._lru)
