"""Paged KV cache: a fixed pool of fixed-size pages + per-request page tables.

The pool is one stacked-leading-layer-dim array per tensor — the same layout
``models/transformer.py`` uses for its dense cache, with the contiguous
sequence axis cut into pages:

    k_pages: [L, P, page_size, Hkv, D]       (int8 payload or bf16)
    k_scale: [L, P, page_size, Hkv, 1]       f32, only when kv_bits < 16

int4 pools pack two nibbles per byte along the head dim (the same "unified
elements" packing the weight path uses), so the payload trailing dim is D//2.

A request owns an ordered list of physical page ids (its *page table*); page
``i`` of the table holds cache positions ``[i*page_size, (i+1)*page_size)``.
Pages are allocated at admission (enough for the prompt), extended one page
at a time as decode crosses a page boundary, and returned when the request
finishes or is preempted.  The free list is LIFO so freed pages are re-used
immediately — fragmentation-free because every page is the same size.

**Sharing.**  Every allocated page carries a refcount so the prefix cache
(``serve/prefix_cache.py``) can map one physical page into many requests'
tables: ``allocate(..., prefix_pages=...)`` adopts already-written pages
read-only, ``fork_page`` copy-on-write-forks a shared page the moment a
request must write into it, and ``free`` only recycles a page when its last
reference drops.  Two hooks connect the pool to a cache layer without the
pool knowing its policy: ``release_hook(page) -> bool`` may retain a
dead page (refcount 0) for future reuse instead of freeing it, and
``reclaim_hook(n) -> list[page]`` surrenders retained pages back when the
free list runs dry — so ``can_allocate`` counts free + reclaimable.

Allocation book-keeping is host-side Python (it runs once per engine step);
the payload arrays live on device and are updated functionally (``.at[]``),
so the jit'd decode step can consume them directly.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_page(pool, src, dst):
    """In-place single-page copy (donation keeps XLA from materializing a
    whole-pool copy for a one-page CoW fork)."""
    return pool.at[:, dst].set(pool[:, src])


@dataclass
class PageCacheStats:
    pages_total: int
    pages_free: int
    high_water: int  # max pages simultaneously in use


class PagedKVCache:
    def __init__(
        self,
        cfg: ArchConfig,
        *,
        num_pages: int,
        page_size: int,
        kv_bits: int = 8,
    ):
        if kv_bits not in (4, 8, 16):
            raise ValueError(f"kv_bits must be 4, 8 or 16, got {kv_bits}")
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.kv_bits = kv_bits
        self.quantized = kv_bits < 16
        n_layers, hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        if kv_bits == 4 and hd % 2:
            raise ValueError(f"kv4 packs nibble pairs along head_dim; hd={hd} is odd")
        payload_dtype = jnp.int8 if self.quantized else jnp.dtype(cfg.dtype)
        dk = hd // 2 if kv_bits == 4 else hd  # packed payload trailing dim
        shape = (n_layers, num_pages, page_size, hkv, dk)
        self.k = jnp.zeros(shape, payload_dtype)
        self.v = jnp.zeros(shape, payload_dtype)
        if self.quantized:
            sshape = (n_layers, num_pages, page_size, hkv, 1)
            self.k_scale = jnp.zeros(sshape, jnp.float32)
            self.v_scale = jnp.zeros(sshape, jnp.float32)
        else:
            self.k_scale = None
            self.v_scale = None
        self._free: list[int] = list(range(num_pages - 1, -1, -1))  # LIFO
        self._tables: dict[int, list[int]] = {}
        self._refcount: dict[int, int] = {}  # pages not on the free list
        self._high_water = 0
        # prefix-cache hooks (see module docstring); None = plain pool
        self.release_hook: Optional[Callable[[int], bool]] = None
        self.reclaim_hook: Optional[Callable[[int], list[int]]] = None
        self.reclaimable_fn: Optional[Callable[[], int]] = None

    # ------------------------------------------------------------ bookkeeping
    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_reclaimable(self) -> int:
        """Pages retained by the cache layer that eviction could free."""
        return self.reclaimable_fn() if self.reclaimable_fn else 0

    @property
    def num_allocatable(self) -> int:
        return len(self._free) + self.num_reclaimable

    def can_allocate(self, n_pages: int) -> bool:
        return self.num_allocatable >= n_pages

    def refcount(self, page: int) -> int:
        return self._refcount.get(page, 0)

    def _pop_pages(self, n_pages: int) -> list[int]:
        """Take n fresh pages, evicting retained cache pages if needed."""
        if len(self._free) < n_pages and self.reclaim_hook:
            self._free.extend(self.reclaim_hook(n_pages - len(self._free)))
        if len(self._free) < n_pages:
            raise MemoryError(
                f"need {n_pages} pages, {len(self._free)} free of {self.num_pages}"
            )
        pages = [self._free.pop() for _ in range(n_pages)]
        for p in pages:
            self._refcount[p] = 1
        return pages

    def allocate(
        self, rid: int, n_pages: int, *, prefix_pages: tuple[int, ...] = ()
    ) -> list[int]:
        """Build rid's table: ``prefix_pages`` adopted shared (incref'd, must
        already be live or cache-retained), the remainder fresh from the pool.
        ``n_pages`` is the *total* table length."""
        if rid in self._tables:
            raise KeyError(f"request {rid} already holds pages")
        if len(prefix_pages) > n_pages:
            raise ValueError("prefix_pages longer than the requested table")
        # incref the adopted pages FIRST so a reclaim for the fresh remainder
        # can never evict them out from under this request
        for p in prefix_pages:
            self.incref(p)
        try:
            fresh = self._pop_pages(n_pages - len(prefix_pages))
        except MemoryError:
            for p in prefix_pages:
                self.decref(p)
            raise
        self._tables[rid] = list(prefix_pages) + fresh
        self._note_usage()
        return self._tables[rid]

    def extend(self, rid: int, n_pages: int = 1) -> list[int]:
        pages = self._pop_pages(n_pages)
        self._tables[rid].extend(pages)
        self._note_usage()
        return pages

    def release_retained(self, page: int) -> None:
        """Return a cache-retained page (refcount 0, held out of the free
        list by the release hook) to the free list — the cache layer calls
        this when it drops such a page's entry and nothing else will ever
        free it."""
        if self.refcount(page) != 0:
            raise ValueError(f"page {page} is still referenced")
        self._free.append(page)

    def incref(self, page: int) -> None:
        """Add a reference to a live or cache-retained page.  Retained pages
        (refcount 0, held out of the free list by the release hook) revive to
        refcount 1; the cache layer must un-track them on its side."""
        self._refcount[page] = self._refcount.get(page, 0) + 1
        self._note_usage()

    def decref(self, page: int) -> None:
        n = self._refcount.get(page, 0) - 1
        if n < 0:
            raise ValueError(f"page {page} refcount underflow")
        if n > 0:
            self._refcount[page] = n
            return
        del self._refcount[page]
        # last reference gone: the cache layer may retain the page for
        # future prefix hits; otherwise it returns to the free list
        if self.release_hook is not None and self.release_hook(page):
            return
        self._free.append(page)

    def free(self, rid: int) -> None:
        for page in reversed(self._tables.pop(rid)):
            self.decref(page)

    def truncate(self, rid: int, n_tokens: int) -> list[int]:
        """Shrink rid's table to the pages covering its first ``n_tokens``
        positions, dropping the reference to every tail page (speculative
        rollback: rejected draft tokens may have grown the table past the
        accepted length).  Returns the dropped page ids — shared pages only
        lose this request's reference; a dropped page whose last reference
        this was goes through the normal release hook (so the caller must
        ``PrefixCache.forget_pages`` any page whose *content* the rollback
        invalidated BEFORE truncating, or the cache would retain it)."""
        if n_tokens < 0:
            raise ValueError(f"n_tokens must be >= 0, got {n_tokens}")
        table = self._tables[rid]
        keep = self.pages_for(n_tokens)
        if keep >= len(table):
            return []
        dropped = table[keep:]
        # replace rather than mutate: allocate()/extend() hand out the live
        # table list, so callers may still hold an alias of the old one
        self._tables[rid] = table[:keep]
        for page in reversed(dropped):
            self.decref(page)
        return dropped

    def fork_page(self, rid: int, idx: int) -> int:
        """Copy-on-write: replace slot ``idx`` of rid's table with a private
        copy of the page (payload + scales copied on device), dropping the
        reference to the shared original.  Returns the new page id."""
        old = self._tables[rid][idx]
        (new,) = self._pop_pages(1)
        self.k = _copy_page(self.k, old, new)
        self.v = _copy_page(self.v, old, new)
        if self.quantized:
            self.k_scale = _copy_page(self.k_scale, old, new)
            self.v_scale = _copy_page(self.v_scale, old, new)
        self._tables[rid][idx] = new
        self.decref(old)
        return new

    def table(self, rid: int) -> list[int]:
        return list(self._tables[rid])

    def capacity_tokens(self, rid: int) -> int:
        """Cache positions currently addressable by rid's page table."""
        return len(self._tables[rid]) * self.page_size

    def table_array(self, rids: list[int], width: int) -> np.ndarray:
        """[B, width] int32 page-table matrix, zero-padded (padded entries
        gather page 0; they are masked out by per-row lengths downstream).
        Host-side np so the engine can batch-pad without a device
        round-trip; jit'd consumers convert on entry."""
        out = np.zeros((len(rids), width), np.int32)
        for i, rid in enumerate(rids):
            t = self._tables[rid]
            out[i, : len(t)] = t
        return out

    def stats(self) -> PageCacheStats:
        return PageCacheStats(self.num_pages, len(self._free), self._high_water)

    def _note_usage(self) -> None:
        self._high_water = max(self._high_water, self.num_pages - len(self._free))

    # -------------------------------------------------------------- payloads
    def set_pools(self, k, v, k_scale=None, v_scale=None) -> None:
        """Adopt pool arrays returned by the jitted decode step (which
        scatters each new token into its page in-kernel)."""
        self.k = k
        self.v = v
        if self.quantized:
            self.k_scale = k_scale
            self.v_scale = v_scale

    def write_prompt(self, rid: int, k, v, k_scale=None, v_scale=None) -> None:
        """Scatter a prefilled contiguous cache row into this request's pages.

        k/v: [L, S_pad, Hkv, Dk] with S_pad == len(table) * page_size (the
        engine prefills with max_len rounded up to a page multiple).
        """
        pages = jnp.asarray(self._tables[rid], jnp.int32)
        n, ps = len(self._tables[rid]), self.page_size
        if k.shape[1] != n * ps:
            raise ValueError(f"prompt cache len {k.shape[1]} != {n}*{ps}")

        def scatter(pool, row):
            paged = row.reshape(row.shape[0], n, ps, *row.shape[2:])
            return pool.at[:, pages].set(paged.astype(pool.dtype))

        self.k = scatter(self.k, k)
        self.v = scatter(self.v, v)
        if self.quantized:
            self.k_scale = scatter(self.k_scale, k_scale)
            self.v_scale = scatter(self.v_scale, v_scale)

    def write_token(self, rids: list[int], positions: np.ndarray, new_kv) -> None:
        """Write one new token's K/V for a batch of requests.

        positions[i] is the cache position of request rids[i]'s new token;
        new_kv is (k, v[, k_scale, v_scale]) with k/v [L, B, Hkv, Dk].
        """
        page_ids = np.array(
            [self._tables[r][p // self.page_size] for r, p in zip(rids, positions)],
            np.int32,
        )
        offs = jnp.asarray(positions % self.page_size, jnp.int32)
        page_ids = jnp.asarray(page_ids)

        def scatter(pool, new):
            return pool.at[:, page_ids, offs].set(new.astype(pool.dtype))

        if self.quantized:
            k, v, ks, vs = new_kv
            self.k_scale = scatter(self.k_scale, ks)
            self.v_scale = scatter(self.v_scale, vs)
        else:
            k, v = new_kv
        self.k = scatter(self.k, k)
        self.v = scatter(self.v, v)
