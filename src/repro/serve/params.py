"""Structured request parameters for the generation API.

``SamplingParams`` is everything that shapes the *token stream* — how many
tokens, how they are chosen (temperature / top-k / top-p under a per-request
seed), and what terminates them.  ``PrecisionParams`` is everything that
shapes the *compute* — which quantized weight set runs the request's kernel
calls, the KV-cache payload precision, and the self-speculative decoding
knobs.  The split mirrors the engine's own layering: sampling rides the
logits at the end of every jitted hot path, precision picks which hot path
(kernel group) the request batches into.

Both are frozen: a submitted request's parameters are immutable, so one
instance can be shared across many ``submit()`` calls (the engine never
mutates them) and grouping keys stay stable for a request's whole life.

Determinism contract (tested in tests/test_sampling.py):

* ``temperature == 0.0`` (the default) is greedy argmax — bit-identical to
  the pre-sampling engine, whatever ``seed``/``top_k``/``top_p`` say.
* ``temperature > 0`` draws token position ``p`` with the PRNG key
  ``fold_in(PRNGKey(seed), p)`` (kernels/ops.py::sample_keys), so a fixed
  seed reproduces the stream exactly — independent of batch composition,
  pow2 bucketing, or preempt/recompute cycles.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

_BITS = (4, 8, 16)


@dataclass(frozen=True)
class SamplingParams:
    """How a request's tokens are chosen and when the stream stops.

    temperature: 0.0 = greedy argmax (default); > 0 softmax-samples the
        (top-k/top-p masked) logits at ``logits / temperature``.
    top_k: keep only the k highest logits before sampling (0 = disabled).
    top_p: nucleus sampling — keep the smallest set of tokens whose
        cumulative probability reaches top_p (1.0 = disabled).
    seed: per-request PRNG seed; token position p uses key
        fold_in(PRNGKey(seed), p), so streams are reproducible and
        batch-composition independent.
    max_new_tokens: token budget; the request finishes when it is spent.
    eos_id / stop_tokens: emitting any of these finishes the request
        immediately (the stop token itself is kept in the output).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    stop_tokens: tuple[int, ...] = ()

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}"
            )
        if not 0 <= self.seed < 2**32:
            raise ValueError(
                f"seed must fit uint32 (0 <= seed < 2**32), got {self.seed}"
            )
        object.__setattr__(
            self, "stop_tokens", tuple(int(t) for t in self.stop_tokens)
        )

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


@dataclass(frozen=True)
class PrecisionParams:
    """Which compute path serves the request.

    ``None`` fields resolve to the engine's defaults at ``submit()`` time
    (``cfg.serve_w_bits`` / ``cfg.serve_kv_bits`` for the precisions, the
    engine's ``spec_k`` / ``draft_bits`` for speculation), so
    ``PrecisionParams()`` means "whatever the engine was configured with".

    w_bits: weight precision of the request's kernel calls (4 / 8 / 16).
    kv_bits: KV-cache payload precision (4 / 8 = int + scales, 16 = bf16).
    spec_k: speculative draft tokens per round (0 = plain decode).
    draft_bits: weight precision of the speculative draft passes.
    """

    w_bits: Optional[int] = None
    kv_bits: Optional[int] = None
    spec_k: Optional[int] = None
    draft_bits: Optional[int] = None

    def __post_init__(self):
        for name in ("w_bits", "kv_bits", "draft_bits"):
            val = getattr(self, name)
            if val is not None and val not in _BITS:
                raise ValueError(f"{name} must be one of {_BITS}, got {val}")
        if self.spec_k is not None and self.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {self.spec_k}")


# Names submit()'s deprecated-kwargs shim still accepts, and the structured
# type each one now lives in (serve/engine.py warns and converts).
LEGACY_SAMPLING_KWARGS = frozenset(
    {"max_new_tokens", "eos_id", "stop_tokens"}
)
LEGACY_PRECISION_KWARGS = frozenset(
    {"w_bits", "kv_bits", "spec_k", "draft_bits"}
)
