"""Request lifecycle for the continuous-batching engine.

A request carries its own precision choice — ``w_bits`` selects which
quantized weight set (W4/W8 via ``models.transformer.quantize_params``, 16 =
raw bf16) its kernel calls run against, ``kv_bits`` selects the KV-cache
payload (4/8 = int + per-(token, head) scales, 16 = bf16).  The engine groups
same-``group_key`` requests into one batched kernel call per decode step.
The user-facing structured forms of these knobs are
``serve/params.py::SamplingParams`` / ``PrecisionParams``; the engine
flattens them onto the request at ``submit()`` so grouping and the jitted
hot paths read plain fields.

``spec_k > 0`` opts the request into **self-speculative decoding**: each
engine round drafts up to ``spec_k`` tokens with the cheap ``draft_bits``
weight set and verifies them in one multi-token pass at the request's own
``w_bits`` (serve/spec_decode.py).  Greedy requests accept on exact token
equality (emitted stream identical to plain greedy decode); sampled requests
run speculative *rejection* sampling, which matches the target distribution
exactly without matching any particular plain-sampled stream bit-for-bit.

Termination: a request finishes when it has emitted ``max_new_tokens``
(``finish_reason == "length"``), or the moment it emits ``eos_id`` / any
token in ``stop_tokens`` (``"stop"``, token kept) — in prefill, plain
decode, and the speculative verify path alike.  A request whose context can
never fit the page pool is FAILED (``"failed"``) with ``error`` set.

Recompute-style preemption is safe for both decode modes: a preempted
request re-prefills ``prompt + out_tokens[:-1]`` and continues — greedy
deterministically, sampled because token position ``p`` always draws with
the key ``fold_in(PRNGKey(seed), p)``, so the replayed continuation redraws
the same tokens it would have drawn uninterrupted.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"
    FAILED = "failed"  # rejected at admission (e.g. context can never fit)


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    w_bits: int = 8  # weight precision for this request's kernel calls
    kv_bits: int = 8  # KV-cache payload precision (8=int8+scales, 16=bf16)
    eos_id: int | None = None  # finish on emitting this token
    stop_tokens: tuple[int, ...] = ()  # additional stop token ids
    spec_k: int = 0  # speculative draft tokens per round (0 = plain decode)
    draft_bits: int = 4  # weight precision of the speculative draft passes
    temperature: float = 0.0  # 0 = greedy argmax; > 0 samples
    top_k: int = 0  # keep k highest logits (0 = disabled)
    top_p: float = 1.0  # nucleus mass (1.0 = disabled)
    seed: int = 0  # per-request PRNG seed (position-keyed, see params.py)
    arrival: int = 0  # engine-assigned admission-order ticket (FCFS key)
    state: RequestState = RequestState.WAITING
    out_tokens: list[int] = field(default_factory=list)
    cache_len: int = 0  # tokens currently materialized in the KV cache
    preemptions: int = 0
    submit_ts: float = 0.0  # perf_counter at submit (TTFT reference point)
    ttft: float | None = None  # submit -> first output token, seconds
    error: str | None = None  # set when state is FAILED
    finish_reason: Optional[str] = None  # "stop" | "length" | "failed"
    spec_drafted: int = 0  # this request's drafted tokens (spec rounds)
    spec_accepted: int = 0  # drafts the verify accepted AND emission used

    @property
    def done(self) -> bool:
        return self.state is RequestState.FINISHED

    @property
    def failed(self) -> bool:
        return self.state is RequestState.FAILED

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    @property
    def group_key(self) -> tuple[int, int]:
        """(w_bits, kv_bits) — requests with equal keys batch together."""
        return (self.w_bits, self.kv_bits)

    @property
    def spec_group_key(self) -> tuple[int, int, int]:
        """(w_bits, draft_bits, kv_bits) — speculative rounds batch requests
        that share both the draft and the verify weight set."""
        return (self.w_bits, self.draft_bits, self.kv_bits)

    def is_stop(self, tok: int) -> bool:
        """True when emitting ``tok`` must terminate the request."""
        return (self.eos_id is not None and tok == self.eos_id) or (
            tok in self.stop_tokens
        )

    def feed_tokens(self) -> np.ndarray:
        """Tokens a (re-)prefill must materialize in the cache: the prompt
        plus every generated token already *fed* back to the model (all but
        the newest, which the next decode step feeds)."""
        if self.out_tokens:
            return np.concatenate(
                [self.prompt, np.asarray(self.out_tokens[:-1], np.int32)]
            )
        return self.prompt
