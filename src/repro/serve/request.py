"""Request lifecycle for the continuous-batching engine.

A request carries its own precision choice — ``w_bits`` selects which
quantized weight set (W4/W8 via ``models.transformer.quantize_params``, 16 =
raw bf16) its kernel calls run against, ``kv_bits`` selects the KV-cache
payload (8 = int8 + per-(token, head) scales, 16 = bf16).  The engine groups
same-``group_key`` requests into one batched kernel call per decode step.

Decoding is greedy, which is what makes recompute-style preemption safe: a
preempted request re-prefills ``prompt + out_tokens[:-1]`` and continues
deterministically.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field

import numpy as np


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    w_bits: int = 8  # weight precision for this request's kernel calls
    kv_bits: int = 8  # KV-cache payload precision (8=int8+scales, 16=bf16)
    arrival: int = 0  # engine-assigned admission-order ticket (FCFS key)
    state: RequestState = RequestState.WAITING
    out_tokens: list[int] = field(default_factory=list)
    cache_len: int = 0  # tokens currently materialized in the KV cache
    preemptions: int = 0
    submit_ts: float = 0.0  # perf_counter at submit (TTFT reference point)
    ttft: float | None = None  # submit -> first output token, seconds

    @property
    def done(self) -> bool:
        return self.state is RequestState.FINISHED

    @property
    def group_key(self) -> tuple[int, int]:
        """(w_bits, kv_bits) — requests with equal keys batch together."""
        return (self.w_bits, self.kv_bits)

    def feed_tokens(self) -> np.ndarray:
        """Tokens a (re-)prefill must materialize in the cache: the prompt
        plus every generated token already *fed* back to the model (all but
        the newest, which the next decode step feeds)."""
        if self.out_tokens:
            return np.concatenate(
                [self.prompt, np.asarray(self.out_tokens[:-1], np.int32)]
            )
        return self.prompt
