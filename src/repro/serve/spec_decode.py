"""Self-speculative decoding: W4 draft, exact target-precision verify.

SPEED's premise is that one precision-scalable datapath trades bits for
throughput on the *same* weights (paper Sec. II-B).  The serving engine
already exploits that per-request (each request picks its ``w_bits``); this
module exploits it **per token**: the cheap low-bit weight set drafts ahead,
the request's own target precision verifies, and exact greedy acceptance
turns the multi-precision machinery from a quality knob into a latency
multiplier.

One speculative round for a batch of same-``(w_bits, draft_bits, kv_bits)``
requests is ONE jitted call (:func:`spec_decode_round`):

  1. **Draft** — ``spec_k`` greedy single-token steps at ``draft_bits``
     (``serve/decode.py::paged_decode_step`` against the request's own paged
     KV cache), chained on-device: each step's argmax feeds the next, so a
     round costs one host dispatch + one sync instead of ``spec_k + 1``.
     Draft K/V is scattered into the request's pages as it goes (draft step
     ``i+1`` must attend to draft tokens ``1..i``).
  2. **Verify** — the window ``[last_token, d_1, .., d_k]`` runs ONE
     multi-token pass at the request's target ``w_bits`` through the chunked
     -prefill kernel (``ops.paged_mqa_verify`` — a verify window *is* a
     causal self-chunk), producing target-greedy tokens at every window
     position.  The verify's target-precision K/V overwrites the draft K/V
     in the pages, so verify logits never depend on draft state: they are
     exactly what plain greedy decode would compute.
  3. **Accept** — fused in the same call: draft ``d_i`` is accepted iff it
     equals the target token at window position ``i-1`` and every earlier
     draft was accepted.  Because both sides decode greedily, acceptance is
     *exact token equality* — an accepted draft IS the target token, so the
     emitted tokens are simply the first ``accept + 1`` target tokens
     (``+1``: the verify's own next-token prediction rides along free).
     Spec-on output is therefore identical to spec-off output, which keeps
     the recompute-preemption safety invariant (serve/request.py) intact.

The host engine then advances ``cache_len`` by the emitted count and rolls
back rejected tail positions via ``PagedKVCache.truncate`` (dropping
now-empty tail pages back to the pool, after un-registering any prefix-cache
block whose page content the rejected window overwrote).  Positions between
the new ``cache_len`` and the end of the verify window hold K/V of rejected
tokens, but ``cache_len`` masking means they are never attended and the next
round overwrites them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import dense
from repro.serve.decode import paged_decode_step
from repro.serve.prefill import chunk_forward


def spec_decode_round(
    draft_params,  # param tree quantized at draft_bits
    params,  # param tree at the group's target w_bits
    tokens: jnp.ndarray,  # [B, 1] int32 — last emitted token per request
    lengths: jnp.ndarray,  # [B] int32 — tokens already in the cache
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    valid: jnp.ndarray,  # [B] bool — False for pow2-bucket padding rows
    n_draft: jnp.ndarray,  # [B] int32 — draft tokens this row runs (<= spec_k)
    pool_k: jnp.ndarray,  # [L, P, ps, Hkv, Dk]
    pool_v: jnp.ndarray,
    pool_ks,  # [L, P, ps, Hkv, 1] f32 or None (kv_bits == 16)
    pool_vs,
    *,
    cfg: ArchConfig,
    spec_k: int,  # static: draft steps unrolled in the jitted graph
    mesh=None,
):
    """One fused draft+verify+accept round.

    Returns ``(target_tokens [B, spec_k+1], accept [B], new_pools)``: row b
    emits ``target_tokens[b, : accept[b] + 1]`` (``accept[b] <= n_draft[b]``,
    so a row never emits past its clipped window).  Every row's table must
    cover positions ``[0, lengths[b] + n_draft[b] + 1)`` — the engine
    guarantees this via ``_ensure_page_room`` (which degrades ``n_draft``
    before evicting anyone).  Not jit'd here: the engine jits a closure over
    its mesh, mirroring decode/prefill.
    """
    pools = (pool_k, pool_v, pool_ks, pool_vs)
    window = [tokens]
    tok = tokens
    # --- draft: spec_k greedy steps at draft_bits, chained on-device.  Rows
    # past their own n_draft keep computing (the graph is static) but stop
    # appending K/V (valid=False drops the scatter) and their surplus drafts
    # can't be accepted (the accept mask below caps at n_draft).
    for i in range(spec_k):
        step_valid = valid & (i < n_draft)
        logits, pools = paged_decode_step(
            draft_params, tok, lengths + i, tables, step_valid, *pools,
            cfg=cfg, mesh=mesh,
        )
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        window.append(tok)
    wtok = jnp.concatenate(window, axis=1)  # [B, spec_k + 1]

    # --- verify: one causal self-chunk at the target precision.  ctx_lens =
    # round-start lengths, so verify attends only to committed cache + the
    # window itself — never to draft K/V — and its scatter overwrites the
    # draft K/V with target-precision values.
    q_lens = jnp.where(valid, n_draft + 1, 0).astype(jnp.int32)
    x, pools = chunk_forward(
        params, wtok, lengths, q_lens, tables, *pools,
        cfg=cfg, mesh=mesh, verify=True,
    )
    logits = dense(x, params["unembed"]).astype(jnp.float32)  # [B, C, V]
    logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e30)
    tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [B, C]

    # --- fused accept-length: longest draft prefix matching the target
    drafts = wtok[:, 1:]  # [B, spec_k]
    in_window = jnp.arange(spec_k, dtype=jnp.int32)[None, :] < n_draft[:, None]
    match = (drafts == tgt[:, :-1]) & in_window
    accept = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    return tgt, accept, pools


def plan_windows(
    reqs, capacities: np.ndarray, spec_k: int
) -> np.ndarray:
    """Per-row draft budget for one round: each request drafts at most its
    own ``spec_k``, clipped so the round (a) never emits past
    ``max_new_tokens`` (mid-window budget clipping — the verify's bonus
    token occupies one slot) and (b) never writes past the pages the pool
    could actually grant (``_ensure_page_room`` degrades under pressure
    rather than evicting for speculation)."""
    n_draft = np.zeros(len(reqs), np.int32)
    for i, r in enumerate(reqs):
        remaining = r.max_new_tokens - len(r.out_tokens)
        room = int(capacities[i]) - r.cache_len - 1  # window writes n_draft+1
        n_draft[i] = max(0, min(r.spec_k, spec_k, remaining - 1, room))
    return n_draft


def clip_stop(req, emitted: list[int]) -> tuple[list[int], bool]:
    """Mid-window stop-token clipping: cut ``emitted`` after the first stop
    token (kept, like plain decode keeps it).  Returns (tokens, stopped)."""
    for j, tok in enumerate(emitted):
        if req.is_stop(tok):
            return emitted[: j + 1], True
    return emitted, False
