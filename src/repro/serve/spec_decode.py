"""Self-speculative decoding: cheap-precision draft, target-precision verify.

SPEED's premise is that one precision-scalable datapath trades bits for
throughput on the *same* weights (paper Sec. II-B).  The serving engine
already exploits that per-request (each request picks its ``w_bits``); this
module exploits it **per token**: the cheap low-bit weight set drafts ahead,
the request's own target precision verifies, and acceptance turns the
multi-precision machinery from a quality knob into a latency multiplier.

One speculative round for a batch of same-``(w_bits, draft_bits, kv_bits)``
requests is ONE jitted call (:func:`spec_decode_round`):

  1. **Draft** — ``spec_k`` single-token steps at ``draft_bits``
     (``serve/decode.py::paged_decode_step`` against the request's own paged
     KV cache), chained on-device: each step's chosen token feeds the next,
     so a round costs one host dispatch + one sync instead of ``spec_k + 1``.
     Draft tokens are drawn from the draft model's *sampling distribution*
     (``kernels/ops.py::sampling_probs`` — temperature/top-k/top-p masked;
     a one-hot, i.e. plain argmax, for greedy rows) and the per-step
     distributions are kept for the accept test.  Draft K/V is scattered
     into the request's pages as it goes (draft step ``i+1`` must attend to
     draft tokens ``1..i``).
  2. **Verify** — the window ``[last_token, d_1, .., d_k]`` runs ONE
     multi-token pass at the request's target ``w_bits`` through the chunked
     -prefill kernel (``ops.paged_mqa_verify`` — a verify window *is* a
     causal self-chunk), producing target logits (and target sampling
     distributions) at every window position.  The verify's target-precision
     K/V overwrites the draft K/V in the pages, so verify logits never
     depend on draft state.
  3. **Accept** — fused speculative *rejection sampling*
     (:func:`rejection_sample`): draft ``d_i`` is accepted with probability
     ``min(1, p_tgt(d_i) / p_draft(d_i))``; on the first reject the token at
     that position is resampled from the normalized residual
     ``max(p_tgt - p_draft, 0)``, and when every draft survives the verify's
     own next-token prediction rides along free (the "bonus" slot).  The
     emitted stream is therefore distributed EXACTLY as plain sampled decode
     (Leviathan et al.'s guarantee), and for greedy rows every distribution
     is a one-hot, collapsing the whole procedure to exact token equality —
     spec-on greedy output stays bit-identical to spec-off, which keeps the
     recompute-preemption safety invariant (serve/request.py) intact.

Every stochastic draw is position-keyed (``ops.sample_keys``): position
``p`` folds ``(seed, p, salt)`` with distinct salts for the draft sample,
the accept uniform, the residual resample and the bonus emission, so a round
is reproducible under a fixed seed whatever the batch looks like.  Round
*boundaries* (how many tokens each round commits) do depend on acceptance,
so a sampled spec stream matches plain sampled decode in distribution, not
bit-for-bit; greedy streams match exactly.

The host engine then advances ``cache_len`` by the emitted count and rolls
back rejected tail positions via ``PagedKVCache.truncate`` (dropping
now-empty tail pages back to the pool, after un-registering any prefix-cache
block whose page content the rejected window overwrote).  Positions between
the new ``cache_len`` and the end of the verify window hold K/V of rejected
tokens, but ``cache_len`` masking means they are never attended and the next
round overwrites them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models.layers import dense
from repro.serve.decode import paged_decode_step
from repro.serve.prefill import chunk_forward

# Salt constants for the independent draws one emission position needs.
# SALT_EMIT doubles as the plain-decode/prefill emission salt (sample_keys'
# default 0), so a spec round whose window degenerates to the bonus slot
# draws the same stream a plain decode step would.
SALT_EMIT = 0
SALT_DRAFT = 1
SALT_ACCEPT = 2
SALT_RESAMPLE = 3


def rejection_sample(
    drafts: jnp.ndarray,  # [B, K] int32 — draft tokens per window slot
    q_draft: jnp.ndarray,  # [B, K, V] draft sampling distributions
    q_target: jnp.ndarray,  # [B, K+1, V] target sampling distributions
    seeds: jnp.ndarray,  # [B] per-request PRNG seeds
    pos0: jnp.ndarray,  # [B] stream position of each row's window slot 0
    n_draft: jnp.ndarray,  # [B] int32 — live draft slots per row (<= K)
):
    """Fused speculative rejection sampling for one verify window.

    Returns ``(tokens [B, K+1], accept [B])``: row ``b`` emits
    ``tokens[b, : accept[b] + 1]`` — its accepted draft prefix plus either
    the residual resample at the first rejected slot or, when all
    ``n_draft[b]`` drafts survive, the bonus token drawn from the target's
    next-token distribution.  Greedy rows (one-hot distributions) reduce to
    exact token equality: accepted drafts ARE the target argmaxes, and the
    resample/bonus is the target argmax at the cut slot.
    """
    b, k = drafts.shape
    pos = pos0[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]  # [B, K]

    def flat_keys(salt):  # per-(row, slot) keys at the given salt
        return ops.sample_keys(
            jnp.repeat(seeds, k), pos.reshape(-1), salt
        )

    # accept test: u_i < p_tgt(d_i) / p_draft(d_i), first reject cuts
    p_t = jnp.take_along_axis(q_target[:, :k], drafts[..., None], -1)[..., 0]
    p_d = jnp.take_along_axis(q_draft, drafts[..., None], -1)[..., 0]
    if k:
        u = jax.vmap(lambda key: jax.random.uniform(key, ()))(
            flat_keys(SALT_ACCEPT)
        ).reshape(b, k)
    else:
        u = jnp.zeros((b, 0), jnp.float32)
    in_window = jnp.arange(k, dtype=jnp.int32)[None, :] < n_draft[:, None]
    ok = (u < p_t / jnp.maximum(p_d, 1e-20)) & in_window
    accept = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)

    # residual resample at every slot (only the first rejected one is used;
    # distinct position-keyed draws, so computing all K is just vectorized)
    if k:
        resid = jnp.maximum(q_target[:, :k] - q_draft, 0.0)
        rs = resid.sum(-1, keepdims=True)
        # degenerate residual (q_target == q_draft exactly) can only pair
        # with accept-prob 1, but guard the normalize anyway
        resid = jnp.where(rs > 0, resid / jnp.maximum(rs, 1e-20), q_target[:, :k])
        res_tok = ops.sample_from_probs(
            resid.reshape(b * k, -1), flat_keys(SALT_RESAMPLE)
        ).reshape(b, k)
    else:
        res_tok = jnp.zeros((b, 0), jnp.int32)

    # bonus token: all drafts survived -> draw the target's own next token.
    # Emitted at stream position pos0 + n_draft with the plain-emission salt,
    # exactly like a plain decode step at that position would.
    q_bonus = jnp.take_along_axis(
        q_target, n_draft[:, None, None], axis=1
    )[:, 0]
    bonus = ops.sample_from_probs(
        q_bonus, ops.sample_keys(seeds, pos0 + n_draft, SALT_EMIT)
    )

    full = accept >= n_draft
    if k:
        cut = jnp.take_along_axis(
            res_tok, jnp.clip(accept, 0, k - 1)[:, None], axis=1
        )[:, 0]
    else:
        cut = bonus
    final = jnp.where(full, bonus, cut)

    slots = jnp.arange(k + 1, dtype=jnp.int32)[None, :]
    drafts_pad = jnp.pad(drafts, ((0, 0), (0, 1)))
    tokens = jnp.where(
        slots < accept[:, None],
        drafts_pad,
        jnp.where(slots == accept[:, None], final[:, None], 0),
    ).astype(jnp.int32)
    return tokens, accept


def spec_decode_round(
    draft_params,  # param tree quantized at draft_bits
    params,  # param tree at the group's target w_bits
    tokens: jnp.ndarray,  # [B, 1] int32 — last emitted token per request
    lengths: jnp.ndarray,  # [B] int32 — tokens already in the cache
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    valid: jnp.ndarray,  # [B] bool — False for pow2-bucket padding rows
    n_draft: jnp.ndarray,  # [B] int32 — draft tokens this row runs (<= spec_k)
    samp,  # (temperature [B], top_k [B], top_p [B], seed [B], position [B])
    pool_k: jnp.ndarray,  # [L, P, ps, Hkv, Dk]
    pool_v: jnp.ndarray,
    pool_ks,  # [L, P, ps, Hkv, 1] f32 or None (kv_bits == 16)
    pool_vs,
    *,
    cfg: ArchConfig,
    spec_k: int,  # static: draft steps unrolled in the jitted graph
    mesh=None,
):
    """One fused draft+verify+accept round.

    Returns ``(emit_tokens [B, spec_k+1], accept [B], new_pools)``: row b
    emits ``emit_tokens[b, : accept[b] + 1]`` (``accept[b] <= n_draft[b]``,
    so a row never emits past its clipped window).  ``samp is None`` means
    the whole group is greedy: the graph is the pre-sampling exact-equality
    round (argmax drafts, token-match accept, zero sampling compute) — the
    general rejection-sampling path reduces to the same tokens through
    one-hot distributions, but an all-greedy group shouldn't pay vocab-sized
    probability algebra per draft step.  Every row's table must cover
    positions ``[0, lengths[b] + n_draft[b] + 1)`` — the engine guarantees
    this via ``_ensure_page_room`` (which degrades ``n_draft`` before
    evicting anyone).  Not jit'd here: the engine jits a closure over its
    mesh, mirroring decode/prefill.
    """
    pools = (pool_k, pool_v, pool_ks, pool_vs)
    b = tokens.shape[0]
    greedy = samp is None
    if not greedy:
        temps, top_ks, top_ps, seeds, pos0 = samp
    tok = tokens
    drafts = []
    draft_probs = []
    # --- draft: spec_k sampled steps at draft_bits, chained on-device.  Rows
    # past their own n_draft keep computing (the graph is static) but stop
    # appending K/V (valid=False drops the scatter) and their surplus drafts
    # can't be accepted (rejection_sample caps at n_draft).
    for i in range(spec_k):
        step_valid = valid & (i < n_draft)
        logits, pools = paged_decode_step(
            draft_params, tok, lengths + i, tables, step_valid, *pools,
            cfg=cfg, mesh=mesh,
        )
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        else:
            qd = ops.sampling_probs(logits, temps, top_ks, top_ps)
            tok = ops.sample_from_probs(
                qd, ops.sample_keys(seeds, pos0 + i, SALT_DRAFT)
            )[:, None]
            draft_probs.append(qd)
        drafts.append(tok)
    wtok = jnp.concatenate([tokens, *drafts], axis=1)  # [B, spec_k + 1]

    # --- verify: one causal self-chunk at the target precision.  ctx_lens =
    # round-start lengths, so verify attends only to committed cache + the
    # window itself — never to draft K/V — and its scatter overwrites the
    # draft K/V with target-precision values.
    q_lens = jnp.where(valid, n_draft + 1, 0).astype(jnp.int32)
    x, pools = chunk_forward(
        params, wtok, lengths, q_lens, tables, *pools,
        cfg=cfg, mesh=mesh, verify=True,
    )
    logits = dense(x, params["unembed"]).astype(jnp.float32)  # [B, C, V]
    logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e30)

    if greedy:
        # exact-equality accept: emitted tokens ARE the target argmaxes
        tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [B, C]
        dr = wtok[:, 1:]
        in_window = (
            jnp.arange(spec_k, dtype=jnp.int32)[None, :] < n_draft[:, None]
        )
        match = (dr == tgt[:, :-1]) & in_window
        accept = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
        return tgt, accept, pools

    c = spec_k + 1
    rep = lambda a: None if a is None else jnp.repeat(a, c)
    q_tgt = ops.sampling_probs(
        logits.reshape(b * c, -1), rep(temps), rep(top_ks), rep(top_ps)
    ).reshape(b, c, -1)

    # --- fused speculative rejection sampling
    q_draft = (
        jnp.stack(draft_probs, axis=1)
        if spec_k
        else jnp.zeros((b, 0) + (logits.shape[-1],), q_tgt.dtype)
    )
    emit, accept = rejection_sample(
        wtok[:, 1:], q_draft, q_tgt, seeds, pos0, n_draft
    )
    return emit, accept, pools


def plan_windows(
    reqs, capacities: np.ndarray, spec_k: int
) -> np.ndarray:
    """Per-row draft budget for one round: each request drafts at most its
    own ``spec_k``, clipped so the round (a) never emits past
    ``max_new_tokens`` (mid-window budget clipping — the verify's bonus
    token occupies one slot) and (b) never writes past the pages the pool
    could actually grant (``_ensure_page_room`` degrades under pressure
    rather than evicting for speculation)."""
    n_draft = np.zeros(len(reqs), np.int32)
    for i, r in enumerate(reqs):
        remaining = r.max_new_tokens - len(r.out_tokens)
        room = int(capacities[i]) - r.cache_len - 1  # window writes n_draft+1
        n_draft[i] = max(0, min(r.spec_k, spec_k, remaining - 1, room))
    return n_draft


def clip_stop(req, emitted: list[int]) -> tuple[list[int], bool]:
    """Mid-window stop-token clipping: cut ``emitted`` after the first stop
    token (kept, like plain decode keeps it).  Returns (tokens, stopped)."""
    for j, tok in enumerate(emitted):
        if req.is_stop(tok):
            return emitted[: j + 1], True
    return emitted, False
