"""Continuous-batching multi-precision serving engine.

The paper's pitch — one multi-precision datapath serving 4/8/16-bit work —
applied at the serving layer: every in-flight request picks its own weight
precision (W4A16 / W8A16 / bf16) and KV-cache precision (int8 / bf16), and
the engine still batches them.  Same-precision requests are grouped into one
batched kernel call per decode step (mpmm for the projections, the
mqa-decode contract for attention), so a mixed-precision request stream
decodes in a handful of batched calls instead of one model call per request.

Layers (bottom-up):

  * request.py      — ``ServeRequest`` lifecycle (WAITING → RUNNING →
    FINISHED).
  * kv_cache.py     — ``PagedKVCache``: fixed-size page pool + per-request
    page tables, int4/int8-with-scales or bf16 payloads, per-page refcounts
    and copy-on-write forking for cross-request sharing.
  * prefix_cache.py — ``PrefixCache``: block-hash chains mapping full token
    blocks to their pages; LRU eviction of unreferenced pages.
  * scheduler.py    — FCFS admission with head-of-line blocking (no
    starvation) and youngest-first preemption when the page pool runs dry.
  * prefill.py      — jit'd chunked-prefill step (cached prefixes skipped,
    ragged pow2-bucketed suffix chunks, interleaved with decode).
  * decode.py       — jit'd ragged batched decode step over the page pool.
  * spec_decode.py  — fused self-speculative round: k greedy draft steps at
    a cheap weight precision + one exact multi-token verify at the
    request's target precision (bit-identical to plain greedy decode).
  * engine.py       — ``ServeEngine`` tying it together; ``EngineStats``.

Entry points: ``repro.launch.serve`` (CLI), ``repro.train.server.Server``
(compat wrapper), ``examples/serve_quantized.py``, ``benchmarks/serve_bench``.
"""
from repro.serve.engine import EngineStats, ServeEngine
from repro.serve.kv_cache import PagedKVCache
from repro.serve.prefix_cache import PrefixCache, block_hashes
from repro.serve.request import RequestState, ServeRequest
from repro.serve.scheduler import Scheduler

__all__ = [
    "EngineStats",
    "PagedKVCache",
    "PrefixCache",
    "RequestState",
    "Scheduler",
    "ServeEngine",
    "ServeRequest",
    "block_hashes",
]
