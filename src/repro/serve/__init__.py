"""Continuous-batching multi-precision serving engine.

The paper's pitch — one multi-precision datapath serving 4/8/16-bit work —
applied at the serving layer: every in-flight request picks its own weight
precision (W4A16 / W8A16 / bf16) and KV-cache precision (int8 / bf16), and
the engine still batches them.  Same-precision requests are grouped into one
batched kernel call per decode step (mpmm for the projections, the
mqa-decode contract for attention), so a mixed-precision request stream
decodes in a handful of batched calls instead of one model call per request.

Layers (bottom-up):

  * params.py       — ``SamplingParams`` / ``PrecisionParams``: the frozen
    user-facing request knobs (how tokens are chosen vs which compute path
    serves them).
  * outputs.py      — ``StreamEvent`` / ``GenerationOutput``: the streaming
    generation API's per-token and terminal outputs.
  * request.py      — ``ServeRequest`` lifecycle (WAITING → RUNNING →
    FINISHED).
  * kv_cache.py     — ``PagedKVCache``: fixed-size page pool + per-request
    page tables, int4/int8-with-scales or bf16 payloads, per-page refcounts
    and copy-on-write forking for cross-request sharing.
  * prefix_cache.py — ``PrefixCache``: block-hash chains mapping full token
    blocks to their pages; LRU eviction of unreferenced pages.
  * scheduler.py    — FCFS admission with head-of-line blocking (no
    starvation) and youngest-first preemption when the page pool runs dry.
  * prefill.py      — jit'd chunked-prefill step (cached prefixes skipped,
    ragged pow2-bucketed suffix chunks, interleaved with decode).
  * decode.py       — jit'd ragged batched decode step over the page pool.
  * spec_decode.py  — fused self-speculative round: k draft steps at a
    cheap weight precision + one multi-token verify at the request's target
    precision under speculative rejection sampling (bit-identical to plain
    decode for greedy requests, distribution-exact for sampled ones).
  * engine.py       — ``ServeEngine`` tying it together (``submit()`` +
    streaming ``generate()``); ``EngineStats``.

Entry points: ``repro.launch.serve`` (CLI), ``repro.train.server.Server``
(compat wrapper), ``examples/serve_quantized.py``, ``benchmarks/serve_bench``.
"""
from repro.serve.engine import EngineStats, ServeEngine
from repro.serve.kv_cache import PagedKVCache
from repro.serve.outputs import GenerationOutput, StreamEvent
from repro.serve.params import PrecisionParams, SamplingParams
from repro.serve.prefix_cache import PrefixCache, block_hashes
from repro.serve.request import RequestState, ServeRequest
from repro.serve.scheduler import Scheduler

__all__ = [
    "EngineStats",
    "GenerationOutput",
    "PagedKVCache",
    "PrecisionParams",
    "PrefixCache",
    "RequestState",
    "SamplingParams",
    "Scheduler",
    "ServeEngine",
    "ServeRequest",
    "StreamEvent",
    "block_hashes",
]
