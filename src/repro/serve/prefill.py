"""Jit'd chunked-prefill step straight over the paged KV pool.

One call advances a same-precision group of requests through one chunk of
their (uncached) prompt suffix.  Chunk token ``i`` of row ``b`` sits at
absolute position ``q_start[b] + i``; attention reads the pages holding each
row's ``q_start[b]`` already-materialized tokens — the prefix-cache hit plus
earlier chunks — through the page tables inside the kernel
(``models.attention.paged_prefill_attention``), and the chunk attends to
itself causally as a fused term, so no contiguous cache view ever
materializes and no cached token is recomputed.  After the layer scan the
chunk's (quantized) K/V is scattered straight into its pages, exactly like
``serve/decode.py`` scatters a decoded token.

This one function serves both prefill shapes the engine needs:

* **cold bucketed group prefill** — mixed-length admissions padded to one
  pow2 token bucket (``q_lens[b] <= C`` masks the ragged tails) prefill as a
  single call instead of one call per distinct prompt length;
* **warm / long chunked prefill** — a request with a prefix-cache hit (or a
  prompt longer than the chunk budget) advances ``C`` tokens per engine
  step, interleaved with running decodes, with ``q_start`` picking up where
  the cache (or the previous chunk) stopped.

Returns per-row logits at each row's *last valid* chunk position, so the
call that completes a prompt yields the request's first output token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import attention as attn_mod
from repro.models import transformer as model_lib
from repro.models.layers import apply_rope, dense, rms_norm


def bucket_pow2(n: int) -> int:
    """Smallest power of two >= n (jit-shape bucketing)."""
    return 1 << max(n - 1, 0).bit_length()


def chunk_forward(
    params,
    tokens: jnp.ndarray,  # [B, C] int32 — this chunk's tokens (tail-padded)
    q_start: jnp.ndarray,  # [B] int32 — tokens already materialized per row
    q_lens: jnp.ndarray,  # [B] int32 — valid tokens of this chunk (<= C)
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    pool_k: jnp.ndarray,  # [L, P, ps, Hkv, Dk]
    pool_v: jnp.ndarray,
    pool_ks,  # [L, P, ps, Hkv, 1] f32 or None (kv_bits == 16)
    pool_vs,
    *,
    cfg: ArchConfig,
    mesh=None,
    verify: bool = False,
):
    """Run one causal self-chunk through the model: returns (final-normed
    hidden states [B, C, D], new_pools) with the chunk's K/V already
    scattered into its pages — (k, v, k_scale, v_scale), scales None when
    kv_bits == 16.  The caller adopts the returned pools (donation makes the
    scatter in-place).

    This is the shared forward of both chunked prefill
    (:func:`chunk_prefill_step`, which only needs the last valid position's
    logits) and speculative verify (serve/spec_decode.py, which needs every
    window position's logits) — a verify window *is* a causal self-chunk.
    ``verify`` picks the attention entry point
    (``paged_verify_attention`` vs ``paged_prefill_attention``; identical
    kernel contract, separate dispatch for profiling/stats).

    Preconditions: every row's table covers positions ``[0, q_start + q_len)``
    (the engine allocates the full prompt's pages at admission, forking any
    shared page the suffix writes into), and positions ``[0, q_start)`` are
    already materialized in the pool.  Padding positions (``i >= q_lens[b]``)
    never scatter.  Not jit'd here: the engine jits a closure over its mesh,
    mirroring decode."""
    attn_fn = (
        attn_mod.paged_verify_attention if verify
        else attn_mod.paged_prefill_attention
    )
    quant = cfg.serve_kv_bits < 16
    b, c = tokens.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    n_layers = pool_k.shape[0]
    num_pages, page_size = pool_k.shape[1], pool_k.shape[2]
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]  # [B, C, D]
    q_start = q_start.astype(jnp.int32)
    q_lens = q_lens.astype(jnp.int32)
    cpos = jnp.arange(c, dtype=jnp.int32)
    posv = q_start[:, None] + cpos[None, :]  # [B, C] absolute positions
    rows = jnp.arange(b)

    windows = model_lib._per_layer_window(cfg, cfg.n_layers)

    def layer(carry, xs):
        x = carry
        p, li = xs["p"], xs["li"]
        win = xs["win"] if windows is not None else (cfg.window if cfg.window else None)
        xn = rms_norm(x, p["norm1"].astype(x.dtype), cfg.norm_eps)
        q = dense(xn, p["wq"]).reshape(b, c, h, hd)
        k = dense(xn, p["wk"]).reshape(b, c, hkv, hd)
        v = dense(xn, p["wv"]).reshape(b, c, hkv, hd)
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
        if quant:
            kq, ksc = model_lib._quantize_token_kv(k, cfg.serve_kv_bits)
            vq, vsc = model_lib._quantize_token_kv(v, cfg.serve_kv_bits)
            o = attn_fn(
                q, pool_k, pool_v, tables, q_start, q_lens, li, kq, vq,
                window=win, k_scale=pool_ks, v_scale=pool_vs,
                chunk_k_scale=ksc, chunk_v_scale=vsc,
                kv_bits=cfg.serve_kv_bits,
            )
            new_kv = (kq, vq, ksc, vsc)
        else:
            kc = k.astype(pool_k.dtype)
            vc = v.astype(pool_v.dtype)
            o = attn_fn(
                q, pool_k, pool_v, tables, q_start, q_lens, li, kc, vc,
                window=win, kv_bits=cfg.serve_kv_bits,
            )
            new_kv = (kc, vc)
        x = x + dense(o.reshape(b, c, h * hd), p["wo"])
        if cfg.family == "moe":
            m, _ = model_lib._moe_block(p, x, cfg, mesh)
            x = x + m
        else:
            x = x + model_lib._mlp_block(p, x, cfg)
        return x, new_kv

    xs = {"p": params["blocks"], "li": jnp.arange(n_layers, dtype=jnp.int32)}
    if windows is not None:
        xs["win"] = windows
    x, new_kv = jax.lax.scan(layer, x, xs)

    # Scatter the chunk into its pages: position q_start + i lands in table
    # slot (q_start + i) // ps at offset % ps.  Padding positions (and any
    # slot index at/past the padded table width W) get an out-of-range page
    # id, which jax scatters drop.
    page_ids = tables.at[rows[:, None], posv // page_size].get(
        mode="fill", fill_value=num_pages
    )  # [B, C]
    page_ids = jnp.where(cpos[None, :] < q_lens[:, None], page_ids, num_pages)
    offs = posv % page_size

    def scatter(pool, new):  # new: [L, B, C, Hkv, *]
        return pool.at[:, page_ids, offs].set(new.astype(pool.dtype), mode="drop")

    if quant:
        ck, cv, cks, cvs = new_kv
        pools = (
            scatter(pool_k, ck),
            scatter(pool_v, cv),
            scatter(pool_ks, cks),
            scatter(pool_vs, cvs),
        )
    else:
        ck, cv = new_kv
        pools = (scatter(pool_k, ck), scatter(pool_v, cv), None, None)

    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    return x, pools


def chunk_prefill_step(
    params,
    tokens: jnp.ndarray,  # [B, C] int32 — this chunk's tokens (tail-padded)
    q_start: jnp.ndarray,  # [B] int32 — tokens already materialized per row
    q_lens: jnp.ndarray,  # [B] int32 — valid tokens of this chunk (<= C)
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    pool_k: jnp.ndarray,  # [L, P, ps, Hkv, Dk]
    pool_v: jnp.ndarray,
    pool_ks,  # [L, P, ps, Hkv, 1] f32 or None (kv_bits == 16)
    pool_vs,
    *,
    cfg: ArchConfig,
    mesh=None,
):
    """Returns (logits [B, V] at each row's last valid chunk position,
    new_pools); see :func:`chunk_forward` for the contract."""
    x, pools = chunk_forward(
        params, tokens, q_start, q_lens, tables,
        pool_k, pool_v, pool_ks, pool_vs, cfg=cfg, mesh=mesh,
    )
    rows = jnp.arange(x.shape[0])
    last = x[rows, jnp.maximum(q_lens.astype(jnp.int32) - 1, 0)]  # [B, D]
    logits = dense(last, params["unembed"]).astype(jnp.float32)
    logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e30)
    return logits, pools


def chunk_prefill_sample(
    params,
    tokens: jnp.ndarray,  # [B, C] int32 — this chunk's tokens (tail-padded)
    q_start: jnp.ndarray,  # [B] int32 — tokens already materialized per row
    q_lens: jnp.ndarray,  # [B] int32 — valid tokens of this chunk (<= C)
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    samp,  # (temperature [B], top_k [B], top_p [B], seed [B], position [B])
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    pool_ks,
    pool_vs,
    *,
    cfg: ArchConfig,
    mesh=None,
):
    """One prefill chunk *and* the first-token choice, fused in one jitted
    graph: :func:`chunk_prefill_step` for the logits at each row's last
    valid position, then a per-row position-keyed draw
    (``kernels/ops.py::sample_tokens``; greedy rows are exact argmax).  Only
    rows whose prompt completes this chunk use their token — the engine
    discards the rest.  ``samp is None`` (all-greedy group) compiles to the
    bare argmax graph; None ``top_k``/``top_p`` entries elide the mask sorts
    statically.  Returns (first_tokens [B] int32, new_pools)."""
    logits, pools = chunk_prefill_step(
        params, tokens, q_start, q_lens, tables,
        pool_k, pool_v, pool_ks, pool_vs, cfg=cfg, mesh=mesh,
    )
    if samp is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), pools
    temps, top_ks, top_ps, seeds, positions = samp
    keys = ops.sample_keys(seeds, positions)
    return ops.sample_tokens(logits, keys, temps, top_ks, top_ps), pools
