"""ServeEngine: continuous batching with per-request precision.

One engine step:

  1. **Finish/free** — requests that hit their token budget leave the batch
     and return their KV pages to the pool.
  2. **Admit + prefill** — waiting requests are admitted FCFS while batch
     slots and KV pages last (head-of-line blocking, see scheduler.py).
     Admitted requests with identical (w_bits, kv_bits, prompt_len) prefill
     as one batched ``models.transformer.prefill`` call; the resulting
     contiguous cache rows are scattered into their page tables and the
     prefill logits yield each request's first token.
  3. **Grow/evict** — any running request about to cross a page boundary
     gets one more page; if the pool is dry, the youngest running request on
     that pool is preempted (pages freed, recompute-on-readmit — greedy
     decoding makes the replay deterministic).
  4. **Decode** — running requests are grouped by (w_bits, kv_bits); each
     group makes ONE ``paged_decode_step`` call (batched mpmm projections +
     paged-kernel attention reading the page pool in place), which also
     scatters the new K/V token straight into its page — the engine just
     adopts the returned pools.  Batch and table-width dimensions are
     pow2-bucketed so admitting/retiring one request doesn't retrace.  A
     step that decodes ≥2 different precision groups is counted in
     ``stats.mixed_precision_steps``.

Requests never wait for batch-mates: a request admitted at step N starts
decoding at step N alongside requests admitted long before.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as model_lib
from repro.serve.decode import paged_decode_step
from repro.serve.kv_cache import PagedKVCache
from repro.serve.request import RequestState, ServeRequest
from repro.serve.scheduler import Scheduler

_SUPPORTED_FAMILIES = ("dense", "vlm", "audio", "moe")


@dataclass
class EngineStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0  # batched decode kernel-group calls
    engine_steps: int = 0
    tokens_out: int = 0
    prefills: int = 0
    preemptions: int = 0
    mixed_precision_steps: int = 0  # engine steps decoding >= 2 precision groups
    occupancy_sum: int = 0  # sum of decode group sizes (mean = /decode_steps)
    group_calls: dict = field(default_factory=dict)  # (w_bits, kv_bits) -> calls

    @property
    def mean_batch_occupancy(self) -> float:
        return self.occupancy_sum / max(self.decode_steps, 1)

    @property
    def decode_tok_per_s(self) -> float:
        return self.tokens_out / max(self.decode_s, 1e-9)


class ServeEngine:
    @staticmethod
    def supports(cfg: ArchConfig) -> bool:
        """Continuous batching needs every layer's cache in one paged pool:
        attention families only, and no unstacked leading dense MoE blocks."""
        return cfg.family in _SUPPORTED_FAMILIES and not (
            cfg.family == "moe" and cfg.first_dense
        )

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        max_slots: int = 8,
        num_pages: Optional[int] = None,
        page_size: int = 16,
        mesh=None,
    ):
        if not self.supports(cfg):
            raise NotImplementedError(
                f"ServeEngine needs a uniform attention-cache stack "
                f"(families {_SUPPORTED_FAMILIES}, no leading dense MoE blocks); "
                f"{cfg.name} is {cfg.family!r}"
                + (" with first_dense" if cfg.first_dense else "")
                + " — use repro.train.server.Server, which falls back to wave batching"
            )
        self.cfg = cfg
        self.mesh = mesh
        self.page_size = page_size
        self.num_pages = num_pages if num_pages is not None else max_slots * 32
        self._sched = Scheduler(max_slots)
        self._params = {16: params}  # w_bits -> param tree (quantized lazily)
        self._caches: dict[int, PagedKVCache] = {}  # kv_bits -> page pool
        self._next_arrival = 0
        self._next_rid = 0
        self.finished: list[ServeRequest] = []
        self._prefill_fn = functools.partial(
            jax.jit, static_argnames=("cfg", "max_len")
        )(lambda p, b, cfg, max_len: model_lib.prefill(p, b, cfg, max_len, mesh))
        # Donating the pools lets XLA run the fused token-append scatter in
        # place (None scales in the kv16 case contribute no buffers); the
        # engine rebinds via cache.set_pools right after each call and never
        # reuses the old arrays, so the donated buffers are safely dead.
        self._decode_fn = functools.partial(
            jax.jit, static_argnames=("cfg",), donate_argnums=(5, 6, 7, 8)
        )(
            lambda p, t, ln, tb, vl, pk, pv, pks, pvs, cfg: paged_decode_step(
                p, t, ln, tb, vl, pk, pv, pks, pvs, cfg=cfg, mesh=mesh
            )
        )
        self.stats = EngineStats()

    # -------------------------------------------------------------- plumbing
    def params_for(self, w_bits: int):
        if w_bits not in self._params:
            self._params[w_bits] = model_lib.quantize_params(self._params[16], w_bits)
        return self._params[w_bits]

    def cache_for(self, kv_bits: int) -> PagedKVCache:
        if kv_bits not in self._caches:
            self._caches[kv_bits] = PagedKVCache(
                self.cfg,
                num_pages=self.num_pages,
                page_size=self.page_size,
                kv_bits=kv_bits,
            )
        return self._caches[kv_bits]

    def _group_cfg(self, kv_bits: int) -> ArchConfig:
        return dataclasses.replace(self.cfg, serve_kv_bits=kv_bits)

    def _prefill_len(self, req: ServeRequest) -> int:
        return self.cfg.prefix_len + len(req.feed_tokens())

    def _max_ctx(self, req: ServeRequest) -> int:
        return self.cfg.prefix_len + len(req.prompt) + req.max_new_tokens

    # ---------------------------------------------------------------- submit
    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int,
        *,
        w_bits: Optional[int] = None,
        kv_bits: Optional[int] = None,
        rid: Optional[int] = None,
    ) -> ServeRequest:
        w_bits = self.cfg.serve_w_bits if w_bits is None else w_bits
        kv_bits = self.cfg.serve_kv_bits if kv_bits is None else kv_bits
        if w_bits not in (4, 8, 16):
            raise ValueError(f"w_bits must be 4, 8 or 16, got {w_bits}")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if rid is not None:
            live = {
                r.rid for r in (*self._sched.waiting, *self._sched.running)
            }
            if rid in live:
                raise ValueError(f"rid {rid} is already in flight")
        req = ServeRequest(
            rid=self._next_rid if rid is None else rid,
            prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens,
            w_bits=w_bits,
            kv_bits=kv_bits,
            arrival=self._next_arrival,
        )
        self._next_rid = max(self._next_rid, req.rid + 1)
        self._next_arrival += 1
        cache = self.cache_for(kv_bits)
        if cache.pages_for(self._max_ctx(req)) > cache.num_pages:
            raise ValueError(
                f"request needs {cache.pages_for(self._max_ctx(req))} pages; "
                f"pool only has {cache.num_pages}"
            )
        self._sched.submit(req)
        return req

    # --------------------------------------------------------------- prefill
    def _admit_and_prefill(self) -> list[ServeRequest]:
        reserved: dict[int, int] = {}  # kv_bits -> pages spoken for this round

        def fits(req: ServeRequest) -> bool:
            cache = self.cache_for(req.kv_bits)
            need = cache.pages_for(self._prefill_len(req))
            if cache.num_free - reserved.get(req.kv_bits, 0) < need:
                return False
            reserved[req.kv_bits] = reserved.get(req.kv_bits, 0) + need
            return True

        admitted = self._sched.admit(fits)
        if not admitted:
            return []
        groups: dict[tuple, list[ServeRequest]] = {}
        for req in admitted:
            key = (req.w_bits, req.kv_bits, self._prefill_len(req))
            groups.setdefault(key, []).append(req)
        t0 = time.perf_counter()
        for (w_bits, kv_bits, plen), reqs in groups.items():
            self._prefill_group(reqs, w_bits, kv_bits, plen)
        self.stats.prefill_s += time.perf_counter() - t0
        return admitted

    def _prefill_group(self, reqs, w_bits: int, kv_bits: int, plen: int) -> None:
        cfg_g = self._group_cfg(kv_bits)
        cache = self.cache_for(kv_bits)
        max_len = cache.pages_for(plen) * self.page_size
        tokens = jnp.asarray(np.stack([r.feed_tokens() for r in reqs]))
        batch = {"tokens": tokens}
        if self.cfg.prefix_len:
            from repro.models.frontends import prefix_embeddings

            batch["prefix_emb"] = prefix_embeddings(self.cfg, len(reqs))
        logits, kv = self._prefill_fn(self.params_for(w_bits), batch, cfg_g, max_len)
        jax.block_until_ready(logits)
        first = np.asarray(jnp.argmax(logits, axis=-1))
        for i, req in enumerate(reqs):
            cache.allocate(req.rid, cache.pages_for(plen))
            if cache.quantized:
                cache.write_prompt(
                    req.rid, kv["k"][:, i], kv["v"][:, i],
                    kv["k_scale"][:, i], kv["v_scale"][:, i],
                )
            else:
                cache.write_prompt(req.rid, kv["k"][:, i], kv["v"][:, i])
            req.cache_len = plen
            if not req.out_tokens:  # fresh request: prefill yields token #1
                req.out_tokens.append(int(first[i]))
                self.stats.tokens_out += 1
            self.stats.prefills += 1
            if len(req.out_tokens) >= req.max_new_tokens:
                self._finish(req)

    # ---------------------------------------------------------------- decode
    def _ensure_page_room(self) -> None:
        """Grow page tables for requests crossing a page boundary; preempt
        youngest-first when a pool is dry (oldest requests get pages first)."""
        for req in sorted(self._sched.running, key=lambda r: r.arrival):
            if req.state is not RequestState.RUNNING:
                continue
            cache = self.cache_for(req.kv_bits)
            while req.cache_len >= cache.capacity_tokens(req.rid):
                if cache.can_allocate(1):
                    cache.extend(req.rid, 1)
                    continue
                victim = self._sched.pick_victim(kv_bits=req.kv_bits)
                self._preempt(victim)
                if victim is req:
                    break

    def _preempt(self, req: ServeRequest) -> None:
        self.cache_for(req.kv_bits).free(req.rid)
        self._sched.preempt(req)
        self.stats.preemptions += 1

    def _finish(self, req: ServeRequest) -> None:
        self.cache_for(req.kv_bits).free(req.rid)
        self._sched.finish(req)
        self.finished.append(req)

    def _decode_groups(self) -> int:
        groups: dict[tuple[int, int], list[ServeRequest]] = {}
        for req in self._sched.running:
            if req.state is RequestState.RUNNING and req.out_tokens:
                groups.setdefault(req.group_key, []).append(req)
        t0 = time.perf_counter()
        for (w_bits, kv_bits), reqs in sorted(groups.items()):
            reqs.sort(key=lambda r: r.arrival)
            cache = self.cache_for(kv_bits)
            cfg_g = self._group_cfg(kv_bits)
            rids = [r.rid for r in reqs]
            positions = np.array([r.cache_len for r in reqs], np.int64)
            width = max(len(cache.table(r)) for r in rids)
            width = 1 << (width - 1).bit_length()  # pow2-bucket to limit retraces
            # pow2-bucket the batch dimension too, so admitting/retiring one
            # request doesn't retrace the jitted decode step
            n_real = len(reqs)
            bsz = 1 << (n_real - 1).bit_length()
            tables = np.zeros((bsz, width), np.int32)
            tables[:n_real] = cache.table_array(rids, width)
            tokens = np.zeros((bsz, 1), np.int32)
            tokens[:n_real] = np.array([[r.out_tokens[-1]] for r in reqs], np.int32)
            lengths = np.zeros(bsz, np.int32)
            lengths[:n_real] = positions.astype(np.int32)
            valid = np.arange(bsz) < n_real
            logits, new_pools = self._decode_fn(
                self.params_for(w_bits), jnp.asarray(tokens),
                jnp.asarray(lengths), jnp.asarray(tables), jnp.asarray(valid),
                cache.k, cache.v, cache.k_scale, cache.v_scale, cfg=cfg_g,
            )
            jax.block_until_ready(logits)
            cache.set_pools(*new_pools)  # new tokens scattered in-kernel
            next_tok = np.asarray(jnp.argmax(logits[:n_real], axis=-1))
            for i, req in enumerate(reqs):
                req.cache_len += 1
                req.out_tokens.append(int(next_tok[i]))
                self.stats.tokens_out += 1
                if len(req.out_tokens) >= req.max_new_tokens:
                    self._finish(req)
            self.stats.decode_steps += 1
            self.stats.occupancy_sum += len(reqs)
            key = (w_bits, kv_bits)
            self.stats.group_calls[key] = self.stats.group_calls.get(key, 0) + 1
        self.stats.decode_s += time.perf_counter() - t0
        if len(groups) >= 2:
            self.stats.mixed_precision_steps += 1
        return len(groups)

    def step(self) -> bool:
        """One engine iteration; returns True if any work was done."""
        admitted = self._admit_and_prefill()
        self._ensure_page_room()
        n_groups = self._decode_groups()
        self.stats.engine_steps += 1
        return bool(admitted) or n_groups > 0

    def run(self) -> list[ServeRequest]:
        """Drive until every submitted request finishes; returns them
        (completion order)."""
        while self._sched.has_work():
            if not self.step():
                raise RuntimeError(
                    "engine stalled: no request can be admitted "
                    f"(free pages: { {b: c.num_free for b, c in self._caches.items()} })"
                )
        return self.finished
