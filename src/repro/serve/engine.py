"""ServeEngine: continuous batching with per-request precision and
prefix-cache-aware chunked prefill.

One engine step:

  1. **Admit** — waiting requests are admitted FCFS while batch slots and KV
     pages last (head-of-line blocking, see scheduler.py).  Admission looks
     the request's prompt up in the per-pool **prefix cache**
     (prefix_cache.py): the longest chain of cached full token blocks is
     adopted read-only into the request's page table (refcounted sharing),
     capped at ``prompt_len - 1`` so at least one token runs through the
     model to produce the first logits — when that cap lands mid-page, the
     shared page is **copy-on-write forked** before the suffix overwrites
     it.  Only the *uncached* suffix needs fresh pages and compute, so
     admission cost scales with uncached tokens.
  2. **Chunked prefill** (prefill.py) — prefilling requests advance through
     their uncached suffix at most ``prefill_chunk`` tokens per step,
     interleaved with running decodes (long prompts no longer stall the
     batch).  Requests whose remaining suffix fits one chunk are grouped by
     (w_bits, kv_bits, pow2 length bucket) and share ONE
     ``chunk_prefill_step`` call with ragged ``q_lens`` — mixed-length
     admissions no longer pay one trace+call per distinct prompt length.
     The call that completes a prompt yields the request's first token, and
     the request's full prompt blocks are registered back into the prefix
     cache for followers to hit.
  3. **Grow/evict** — any running request about to cross a page boundary
     gets one more page; if the pool is dry the prefix cache's LRU retained
     pages are evicted first, then the youngest running request on that pool
     is preempted.  Preemption *releases* pages into the cache (registering
     every materialized full block), so a preempted request usually resumes
     from still-cached pages and recomputes only what eviction actually
     took.
  4. **Decode** — running requests are grouped by (w_bits, kv_bits); each
     group makes ONE ``paged_decode_step`` call (batched mpmm projections +
     paged-kernel attention reading the page pool in place), which also
     scatters the new K/V token straight into its page — the engine just
     adopts the returned pools.  Batch and table-width dimensions are
     pow2-bucketed so admitting/retiring one request doesn't retrace.  A
     step that decodes ≥2 different precision groups is counted in
     ``stats.mixed_precision_steps``.  Requests with ``spec_k > 0`` instead
     run **speculative rounds** (serve/spec_decode.py): one fused jitted
     call drafts up to ``spec_k`` greedy tokens at the request's cheap
     ``draft_bits`` weight set and verifies the window at its target
     ``w_bits`` through the chunk-attention kernel; exact greedy acceptance
     emits 1..spec_k+1 tokens per round (bit-identical to plain decode),
     and rejected tail pages roll back to the pool via
     ``PagedKVCache.truncate``.

**Generation API** (serve/params.py, serve/outputs.py): ``submit(prompt,
SamplingParams, PrecisionParams)`` enqueues a request; ``generate()``
streams one ``StreamEvent`` per emitted token plus a terminal
``GenerationOutput`` per request.  Every hot path ends in the shared
position-keyed sampling op (``kernels/ops.py::sample_tokens``) inside the
same jitted graph as the model step: per-row temperature/top-k/top-p with
keys ``fold_in(PRNGKey(seed), position)``, so sampled streams are
reproducible under a fixed seed regardless of batch composition, bucketing
or preemption — and ``temperature == 0`` rows are exact argmax, bit-equal
to greedy decode.  Speculative rounds run speculative *rejection* sampling
(serve/spec_decode.py), which preserves the target distribution for sampled
requests and collapses to exact-equality acceptance for greedy ones.

A request finishes on its token budget (``finish_reason == "length"``) OR
the moment it emits its ``eos_id``/``stop_tokens`` (``"stop"`` — prefill,
plain decode, and mid-verify-window alike).  A request whose context
(prompt + max_new_tokens) could never fit its page pool is FAILED
(``"failed"``) at submit/admission with a clear error instead of being
allowed to preempt-readmit-livelock the engine.

Requests never wait for batch-mates: a request admitted at step N starts
prefilling at step N alongside requests decoding since long before.
Archs with frontend prefix embeddings (cfg.prefix_len > 0) keep the legacy
one-shot-prefill path and skip the prefix cache (prefix embeddings are not
token-addressable).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
import warnings
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import transformer as model_lib
from repro.serve.decode import paged_decode_sample
from repro.serve.kv_cache import PagedKVCache
from repro.serve.outputs import (
    FINISH_FAILED,
    FINISH_LENGTH,
    FINISH_STOP,
    GenerationOutput,
    StreamEvent,
)
from repro.serve.params import (
    LEGACY_PRECISION_KWARGS,
    LEGACY_SAMPLING_KWARGS,
    PrecisionParams,
    SamplingParams,
)
from repro.serve.prefill import bucket_pow2, chunk_prefill_sample
from repro.serve.prefix_cache import PrefixCache, block_hashes
from repro.serve.request import RequestState, ServeRequest
from repro.serve.scheduler import Scheduler
from repro.serve.spec_decode import clip_stop, plan_windows, spec_decode_round

_SUPPORTED_FAMILIES = ("dense", "vlm", "audio", "moe")


def _make_jits(mesh):
    """Jitted engine steps closed over ``mesh`` (mesh objects aren't
    hashable jit statics, so it rides in the closure).  The four pool
    arguments of decode/chunk/spec are donated so their in-kernel K/V
    scatters run in place — keep ``donate_argnums`` in sync with the lambda
    signatures here, the single place they are spelled.  ``samp`` is the
    per-row sampling-parameter tuple (temperature, top_k, top_p, seed,
    position) every hot path now ends in: the next-token draw happens inside
    the same jitted graph as the model step, never host-side."""
    prefill = functools.partial(jax.jit, static_argnames=("cfg", "max_len"))(
        lambda p, b, cfg, max_len: model_lib.prefill(p, b, cfg, max_len, mesh)
    )
    decode = functools.partial(
        jax.jit, static_argnames=("cfg",), donate_argnums=(6, 7, 8, 9)
    )(
        lambda p, t, ln, tb, vl, samp, pk, pv, pks, pvs, cfg:
        paged_decode_sample(
            p, t, ln, tb, vl, samp, pk, pv, pks, pvs, cfg=cfg, mesh=mesh
        )
    )
    chunk = functools.partial(
        jax.jit, static_argnames=("cfg",), donate_argnums=(6, 7, 8, 9)
    )(
        lambda p, t, qs, ql, tb, samp, pk, pv, pks, pvs, cfg:
        chunk_prefill_sample(
            p, t, qs, ql, tb, samp, pk, pv, pks, pvs, cfg=cfg, mesh=mesh
        )
    )
    spec = functools.partial(
        jax.jit, static_argnames=("cfg", "spec_k"), donate_argnums=(8, 9, 10, 11)
    )(
        lambda dp, p, t, ln, tb, vl, nd, samp, pk, pv, pks, pvs, cfg, spec_k:
        spec_decode_round(
            dp, p, t, ln, tb, vl, nd, samp, pk, pv, pks, pvs,
            cfg=cfg, spec_k=spec_k, mesh=mesh,
        )
    )
    return prefill, decode, chunk, spec


@functools.lru_cache(maxsize=1)
def _shared_jits():
    """The mesh=None jits, shared process-wide so a fresh engine reuses
    compiled code; meshed engines keep per-engine closures."""
    return _make_jits(None)


@dataclass
class EngineStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0  # batched decode kernel-group calls
    engine_steps: int = 0
    tokens_out: int = 0
    prefills: int = 0  # completed request prefills
    prefill_chunks: int = 0  # chunk_prefill_step calls
    preemptions: int = 0
    mixed_precision_steps: int = 0  # engine steps decoding >= 2 precision groups
    occupancy_sum: int = 0  # sum of decode group sizes (mean = /decode_steps)
    spec_rounds: int = 0  # fused draft+verify group calls
    spec_draft_tokens: int = 0  # tokens drafted at draft_bits
    spec_accepted_tokens: int = 0  # drafts the target verify accepted
    failed: int = 0  # requests rejected at admission (context can't fit)
    group_calls: dict = field(default_factory=dict)  # (w_bits, kv_bits) -> calls
    prefix_hit_tokens: int = 0  # prompt tokens served from cached pages
    prefix_new_tokens: int = 0  # prompt tokens actually computed
    # latency samples for percentile reporting, bounded so a long-lived
    # engine doesn't grow them forever (recent window is what p50/p99 mean)
    ttfts: collections.deque = field(
        default_factory=lambda: collections.deque(maxlen=4096)
    )  # submit -> first token, seconds
    decode_call_s: collections.deque = field(
        default_factory=lambda: collections.deque(maxlen=4096)
    )  # per decode-group call walltime, seconds

    @property
    def mean_batch_occupancy(self) -> float:
        return self.occupancy_sum / max(self.decode_steps, 1)

    @property
    def decode_tok_per_s(self) -> float:
        return self.tokens_out / max(self.decode_s, 1e-9)

    @property
    def spec_accept_rate(self) -> float:
        """Fraction of drafted tokens the target-precision verify accepted."""
        return self.spec_accepted_tokens / max(self.spec_draft_tokens, 1)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prefill tokens served from cached pages
        instead of computed.  Readmissions count too: a preempted request's
        replayed chain (prompt + generated tokens) is prefill work, and
        blocks it re-adopts are recompute genuinely avoided."""
        total = self.prefix_hit_tokens + self.prefix_new_tokens
        return self.prefix_hit_tokens / max(total, 1)


class ServeEngine:
    @staticmethod
    def supports(cfg: ArchConfig) -> bool:
        """Continuous batching needs every layer's cache in one paged pool:
        attention families only, and no unstacked leading dense MoE blocks."""
        return cfg.family in _SUPPORTED_FAMILIES and not (
            cfg.family == "moe" and cfg.first_dense
        )

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        max_slots: int = 8,
        num_pages: Optional[int] = None,
        page_size: int = 16,
        prefill_chunk: int = 32,
        enable_prefix_cache: bool = True,
        spec_k: int = 0,
        draft_bits: int = 4,
        mesh=None,
    ):
        if not self.supports(cfg):
            raise NotImplementedError(
                f"ServeEngine needs a uniform attention-cache stack "
                f"(families {_SUPPORTED_FAMILIES}, no leading dense MoE blocks); "
                f"{cfg.name} is {cfg.family!r}"
                + (" with first_dense" if cfg.first_dense else "")
                + " — use repro.train.server.Server, which falls back to wave batching"
            )
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if draft_bits not in (4, 8, 16):
            raise ValueError(f"draft_bits must be 4, 8 or 16, got {draft_bits}")
        self.spec_k = spec_k  # submit() default: 0 = plain greedy decode
        self.draft_bits = draft_bits  # submit() default draft precision
        self.cfg = cfg
        self.mesh = mesh
        self.page_size = page_size
        self.num_pages = num_pages if num_pages is not None else max_slots * 32
        self.prefill_chunk = prefill_chunk
        # frontend prefix embeddings are not token-addressable: those archs
        # keep the legacy one-shot grouped prefill and no prefix cache
        self._legacy_prefill = bool(cfg.prefix_len)
        self._prefix_enabled = enable_prefix_cache and not self._legacy_prefill
        self._sched = Scheduler(max_slots)
        self._params = {16: params}  # w_bits -> param tree (quantized lazily)
        self._caches: dict[int, PagedKVCache] = {}  # kv_bits -> page pool
        self._prefix: dict[int, PrefixCache] = {}  # kv_bits -> prefix cache
        self._block_hashes: dict[int, list[bytes]] = {}  # rid -> prompt chain
        self._next_arrival = 0
        self._next_rid = 0
        self.finished: list[ServeRequest] = []
        # Donating the pools lets XLA run the fused token-append scatter in
        # place (None scales in the kv16 case contribute no buffers); the
        # engine rebinds via cache.set_pools right after each call and never
        # reuses the old arrays, so the donated buffers are safely dead.
        (self._prefill_fn, self._decode_fn, self._chunk_fn,
         self._spec_fn) = _shared_jits() if mesh is None else _make_jits(mesh)
        self.stats = EngineStats()

    # -------------------------------------------------------------- plumbing
    def params_for(self, w_bits: int):
        if w_bits not in self._params:
            self._params[w_bits] = model_lib.quantize_params(self._params[16], w_bits)
        return self._params[w_bits]

    def cache_for(self, kv_bits: int) -> PagedKVCache:
        if kv_bits not in self._caches:
            self._caches[kv_bits] = PagedKVCache(
                self.cfg,
                num_pages=self.num_pages,
                page_size=self.page_size,
                kv_bits=kv_bits,
            )
            if self._prefix_enabled:
                self._prefix[kv_bits] = PrefixCache(self._caches[kv_bits])
        return self._caches[kv_bits]

    def prefix_cache_for(self, kv_bits: int) -> Optional[PrefixCache]:
        self.cache_for(kv_bits)
        return self._prefix.get(kv_bits)

    def _group_cfg(self, kv_bits: int) -> ArchConfig:
        return dataclasses.replace(self.cfg, serve_kv_bits=kv_bits)

    def _prefill_len(self, req: ServeRequest) -> int:
        return self.cfg.prefix_len + len(req.feed_tokens())

    def _max_ctx(self, req: ServeRequest) -> int:
        """Largest cache the request can ever need: every position its feed
        chain can reach.  The final emitted token is never fed back (the
        request finishes on emission), so the worst-case cache is one short
        of prompt + max_new_tokens — a request sized exactly to the pool
        must admit, not be rejected."""
        return self.cfg.prefix_len + len(req.prompt) + req.max_new_tokens - 1

    def _prefilling(self, req: ServeRequest) -> bool:
        return req.cache_len < self._prefill_len(req)

    def _chain_salt(self, req: ServeRequest) -> tuple:
        # K/V values depend on the weight precision that computed them: W4
        # and W8 requests must never share pages even in the same kv pool
        return ("w", req.w_bits)

    # ---------------------------------------------------------------- submit
    def _legacy_submit_params(
        self, max_new_tokens, sampling, precision, legacy
    ) -> tuple[SamplingParams, PrecisionParams]:
        """Deprecated-kwargs shim: ``submit(prompt, 16, w_bits=4, ...)``
        still works, warning once per call, by packing the flat kwargs into
        the structured types.  Mixing a structured param with flat kwargs
        that belong inside it is an error, not a silent merge."""
        warnings.warn(
            "ServeEngine.submit(prompt, max_new_tokens, **flat_kwargs) is "
            "deprecated; pass submit(prompt, SamplingParams(...), "
            "PrecisionParams(...)) instead",
            DeprecationWarning,
            stacklevel=3,
        )
        unknown = set(legacy) - LEGACY_SAMPLING_KWARGS - LEGACY_PRECISION_KWARGS
        if unknown:
            raise TypeError(f"submit() got unexpected kwargs {sorted(unknown)}")
        samp_kw = {k: v for k, v in legacy.items() if k in LEGACY_SAMPLING_KWARGS}
        prec_kw = {k: v for k, v in legacy.items() if k in LEGACY_PRECISION_KWARGS}
        if max_new_tokens is not None:
            samp_kw["max_new_tokens"] = int(max_new_tokens)
        if sampling is not None and samp_kw:
            raise TypeError(
                f"pass {sorted(samp_kw)} inside SamplingParams, not alongside it"
            )
        if precision is not None and prec_kw:
            raise TypeError(
                f"pass {sorted(prec_kw)} inside PrecisionParams, not alongside it"
            )
        sampling = sampling if sampling is not None else SamplingParams(**samp_kw)
        precision = (
            precision if precision is not None else PrecisionParams(**prec_kw)
        )
        return sampling, precision

    def submit(
        self,
        prompt: np.ndarray,
        sampling: Optional[Union[SamplingParams, int]] = None,
        precision: Optional[PrecisionParams] = None,
        *,
        rid: Optional[int] = None,
        **legacy,
    ) -> ServeRequest:
        """Enqueue one request: ``submit(prompt, SamplingParams(...),
        PrecisionParams(...))``.  Omitted params take the engine defaults
        (greedy, 16 tokens; the engine's configured precisions).  The old
        flat signature ``submit(prompt, max_new_tokens, w_bits=..., ...)``
        still works through a DeprecationWarning shim."""
        if isinstance(sampling, (int, np.integer)) or legacy:
            max_new = sampling if isinstance(sampling, (int, np.integer)) else None
            sampling = None if max_new is not None else sampling
            sampling, precision = self._legacy_submit_params(
                max_new, sampling, precision, legacy
            )
        sampling = SamplingParams() if sampling is None else sampling
        precision = PrecisionParams() if precision is None else precision
        w_bits = (
            self.cfg.serve_w_bits if precision.w_bits is None else precision.w_bits
        )
        kv_bits = (
            self.cfg.serve_kv_bits
            if precision.kv_bits is None
            else precision.kv_bits
        )
        spec_k = self.spec_k if precision.spec_k is None else precision.spec_k
        draft_bits = (
            self.draft_bits
            if precision.draft_bits is None
            else precision.draft_bits
        )
        if rid is not None:
            live = {
                r.rid for r in (*self._sched.waiting, *self._sched.running)
            }
            if rid in live:
                raise ValueError(f"rid {rid} is already in flight")
        req = ServeRequest(
            rid=self._next_rid if rid is None else rid,
            prompt=np.asarray(prompt, np.int32),
            max_new_tokens=sampling.max_new_tokens,
            w_bits=w_bits,
            kv_bits=kv_bits,
            eos_id=sampling.eos_id,
            stop_tokens=sampling.stop_tokens,
            spec_k=spec_k,
            draft_bits=draft_bits,
            temperature=sampling.temperature,
            top_k=sampling.top_k,
            top_p=sampling.top_p,
            seed=sampling.seed,
            arrival=self._next_arrival,
            submit_ts=time.perf_counter(),
        )
        self._next_rid = max(self._next_rid, req.rid + 1)
        self._next_arrival += 1
        cache = self.cache_for(kv_bits)
        if cache.pages_for(self._max_ctx(req)) > cache.num_pages:
            raise ValueError(
                f"request can never fit: prompt + max_new_tokens needs "
                f"{cache.pages_for(self._max_ctx(req))} pages; the kv{kv_bits} "
                f"pool only has {cache.num_pages}"
            )
        self._sched.submit(req)
        return req

    # ------------------------------------------------- admission (prefix-aware)
    def _fail(self, req: ServeRequest, msg: str) -> None:
        """Reject a request that can never run (e.g. its worst-case context
        exceeds the whole page pool): surface a clear error instead of the
        admit -> grow -> self-preempt -> readmit livelock, which ``run()``
        would count as progress forever."""
        if req in self._sched.waiting:
            self._sched.waiting.remove(req)
        req.state = RequestState.FAILED
        req.error = msg
        req.finish_reason = FINISH_FAILED
        self._block_hashes.pop(req.rid, None)
        self.stats.failed += 1
        self.finished.append(req)

    def _admissible(self, req: ServeRequest) -> bool:
        """Cap admissible context against pool capacity: ``submit`` already
        rejects oversized requests, but admission re-checks so a request
        enqueued behind the engine's back (or replayed against a smaller
        pool) fails loudly here instead of livelocking the decode loop."""
        cache = self.cache_for(req.kv_bits)
        need = cache.pages_for(self._max_ctx(req))
        if need <= cache.num_pages:
            return True
        self._fail(
            req,
            f"context can never fit: prompt + max_new_tokens needs {need} "
            f"pages; the kv{req.kv_bits} pool only has {cache.num_pages}",
        )
        return False

    def _try_admit(self, req: ServeRequest) -> bool:
        """Admission check with commitment: on True the request holds its
        full-prompt page table — cached prefix blocks adopted shared, the
        divergence page CoW-forked, fresh pages for the uncached suffix."""
        if not self._admissible(req):
            return False
        cache = self.cache_for(req.kv_bits)
        ps = cache.page_size
        plen = self._prefill_len(req)
        n_pages = cache.pages_for(plen)
        pc = self._prefix.get(req.kv_bits)
        hashes: list[bytes] = []
        pages: list[int] = []
        if pc is not None:
            # memoize the chain across admission retries: a head-of-line
            # request blocked on a full pool is re-checked every engine step,
            # and its feed chain only changes across preempt/readmit cycles
            feed = req.feed_tokens()
            hashes = self._block_hashes.get(req.rid, [])
            if len(hashes) != len(feed) // ps:
                hashes = block_hashes(feed, ps, self._chain_salt(req))
                self._block_hashes[req.rid] = hashes
            pages = pc.match(hashes)
        # at least one suffix token must run through the model to produce the
        # first-token logits, so a full-prompt hit is capped — the capped
        # block's page is then shared *and* about to be written: the
        # copy-on-write divergence fork below.  If the pool can't afford a
        # candidate (the fork needs one extra transient page, and adopted
        # pages can't be reclaimed for their own request), degrade the hit:
        # capped -> floored to a page multiple (no fork) -> cold.
        best = min(len(pages) * ps, plen - 1)
        candidates = [best]
        if best % ps:
            candidates.append(best - best % ps)
        if candidates[-1] != 0:
            candidates.append(0)
        for hit in candidates:
            shared = pages[: -(-hit // ps)] if hit else []
            fork_needed = 1 if hit % ps else 0
            fresh_needed = n_pages - len(shared) + fork_needed
            reclaimable = max(0, cache.num_reclaimable - len(shared))
            if cache.num_free + reclaimable < fresh_needed:
                continue
            try:
                cache.allocate(req.rid, n_pages, prefix_pages=tuple(shared))
            except MemoryError:
                continue
            if pc is not None:
                pc.acquire_note(shared)
                if fork_needed:
                    try:
                        cache.fork_page(req.rid, hit // ps)
                    except MemoryError:
                        cache.free(req.rid)
                        continue
                    pc.stats.forks += 1
            req.cache_len = hit
            if pc is not None:  # both ratio sides counted once, on adoption
                pc.stats.lookups += 1
                pc.stats.lookup_tokens += len(hashes) * ps
                pc.stats.hit_tokens += hit
            self.stats.prefix_hit_tokens += hit
            self.stats.prefix_new_tokens += plen - hit
            return True
        return False

    # ------------------------------------------------------- chunked prefill
    def _prefill_pump(self) -> None:
        """Advance every prefilling request by at most one chunk.  Requests
        finishing this step are grouped by (w_bits, kv_bits, pow2 bucket of
        their remaining suffix) into one ragged call each; longer prompts
        batch into one ``prefill_chunk``-wide ragged call per precision and
        keep the batch decoding between their chunks."""
        pumping = [
            r
            for r in self._sched.running
            if r.state is RequestState.RUNNING and self._prefilling(r)
        ]
        if not pumping:
            return
        pumping.sort(key=lambda r: r.arrival)
        t0 = time.perf_counter()
        groups: dict[tuple, list[ServeRequest]] = {}
        for req in pumping:
            rem = self._prefill_len(req) - req.cache_len
            if rem <= self.prefill_chunk:
                # clamp to the chunk budget: for non-pow2 budgets the pow2
                # bucket could otherwise exceed the per-step token bound
                key = (req.w_bits, req.kv_bits,
                       min(bucket_pow2(rem), self.prefill_chunk))
            else:  # long runners batch too: one ragged call per precision
                key = (req.w_bits, req.kv_bits, "long")
            groups.setdefault(key, []).append(req)
        for key, reqs in sorted(groups.items(), key=lambda kv: kv[1][0].arrival):
            chunk = self.prefill_chunk if key[2] == "long" else key[2]
            self._chunk_group(reqs, chunk)
        self.stats.prefill_s += time.perf_counter() - t0

    def _samp_arrays(self, reqs: list[ServeRequest], bsz: int):
        """Per-row (temperature, top_k, top_p, seed, position) arrays for a
        bucketed group call — or ``None`` when the whole group is greedy, so
        the jitted graph is the bare pre-sampling argmax (zero sampling
        compute; greedy is the default and the common case).  ``top_k`` /
        ``top_p`` entries are likewise ``None`` when no row in the group
        uses them: the vocab argsort the mask needs is elided statically
        (temperature-only sampling costs one gumbel field).  The elided and
        full graphs draw identical tokens for any given row, so grouping
        stays invisible to the stream.

        ``position`` is each request's next emission index
        (= len(out_tokens)) — the PRNG key coordinate that makes sampled
        streams batch-composition and preemption independent.  Padding rows
        stay temperature 0 (greedy argmax of garbage logits, sliced off by
        the caller)."""
        if all(r.greedy for r in reqs):
            return None
        temps = np.zeros(bsz, np.float32)
        top_ks = np.zeros(bsz, np.int32)
        top_ps = np.ones(bsz, np.float32)
        seeds = np.zeros(bsz, np.uint32)
        positions = np.zeros(bsz, np.int32)
        for i, r in enumerate(reqs):
            temps[i] = r.temperature
            top_ks[i] = r.top_k
            top_ps[i] = r.top_p
            seeds[i] = r.seed
            positions[i] = len(r.out_tokens)
        # numpy, not device arrays: the jitted call transfers them with its
        # other host operands instead of five eager device_puts per step
        return (
            temps,
            top_ks if any(r.top_k > 0 for r in reqs) else None,
            top_ps if any(r.top_p < 1.0 for r in reqs) else None,
            seeds,
            positions,
        )

    def _chunk_group(self, reqs: list[ServeRequest], chunk: int) -> None:
        w_bits, kv_bits = reqs[0].w_bits, reqs[0].kv_bits
        cache = self.cache_for(kv_bits)
        cfg_g = self._group_cfg(kv_bits)
        rids = [r.rid for r in reqs]
        n = len(reqs)
        # pow2-bucket the batch dimension like decode does: padding rows have
        # q_len 0, so they scatter nothing and their tokens are sliced off
        bsz = bucket_pow2(n)
        tokens = np.zeros((bsz, chunk), np.int32)
        q_start = np.zeros(bsz, np.int32)
        q_lens = np.zeros(bsz, np.int32)
        for i, r in enumerate(reqs):
            feed = r.feed_tokens()
            q_start[i] = r.cache_len
            q_lens[i] = min(len(feed) - r.cache_len, chunk)
            tokens[i, : q_lens[i]] = feed[r.cache_len : r.cache_len + q_lens[i]]
        width = max(len(cache.table(r)) for r in rids)
        width = bucket_pow2(width)  # pow2-bucket to limit retraces
        tables = np.zeros((bsz, width), np.int32)
        tables[:n] = cache.table_array(rids, width)
        first_tok, new_pools = self._chunk_fn(
            self.params_for(w_bits), jnp.asarray(tokens), jnp.asarray(q_start),
            jnp.asarray(q_lens), jnp.asarray(tables), self._samp_arrays(reqs, bsz),
            cache.k, cache.v, cache.k_scale, cache.v_scale, cfg=cfg_g,
        )
        jax.block_until_ready(first_tok)
        cache.set_pools(*new_pools)  # chunk K/V scattered in-kernel
        self.stats.prefill_chunks += 1
        first = np.asarray(first_tok)
        for i, req in enumerate(reqs):
            req.cache_len += int(q_lens[i])
            if not self._prefilling(req):
                self._on_prefill_done(req, int(first[i]))

    def _on_prefill_done(self, req: ServeRequest, first_token: int) -> None:
        self.stats.prefills += 1
        if not req.out_tokens:  # fresh request: prefill yields token #1
            req.out_tokens.append(first_token)
            self.stats.tokens_out += 1
            req.ttft = time.perf_counter() - req.submit_ts
            self.stats.ttfts.append(req.ttft)
        # register the prompt's full blocks so followers (and this request's
        # own readmission) hit them
        self._register_blocks(req)
        if len(req.out_tokens) >= req.max_new_tokens or req.is_stop(
            req.out_tokens[-1]
        ):
            self._finish(req)

    def _register_blocks(self, req: ServeRequest) -> None:
        pc = self._prefix.get(req.kv_bits)
        if pc is None or req.cache_len < pc.block:
            return
        cache = self.cache_for(req.kv_bits)
        feed = req.feed_tokens()[: req.cache_len]
        hashes = self._block_hashes.get(req.rid, [])
        n_known = len(hashes)
        n_blocks = len(feed) // pc.block
        if n_blocks > n_known:  # decode extended the chain past the prompt
            hashes = block_hashes(feed, pc.block, self._chain_salt(req))
            self._block_hashes[req.rid] = hashes
        pc.register(hashes[:n_blocks], cache.table(req.rid)[:n_blocks])

    # --------------------------------------------- legacy prefill (prefix_len)
    def _admit_and_prefill(self) -> list[ServeRequest]:
        reserved: dict[int, int] = {}  # kv_bits -> pages spoken for this round

        def fits(req: ServeRequest) -> bool:
            if not self._admissible(req):
                return False
            cache = self.cache_for(req.kv_bits)
            need = cache.pages_for(self._prefill_len(req))
            if cache.num_free - reserved.get(req.kv_bits, 0) < need:
                return False
            reserved[req.kv_bits] = reserved.get(req.kv_bits, 0) + need
            return True

        admitted = self._sched.admit(fits)
        if not admitted:
            return []
        groups: dict[tuple, list[ServeRequest]] = {}
        for req in admitted:
            key = (req.w_bits, req.kv_bits, self._prefill_len(req))
            groups.setdefault(key, []).append(req)
        t0 = time.perf_counter()
        for (w_bits, kv_bits, plen), reqs in groups.items():
            self._prefill_group(reqs, w_bits, kv_bits, plen)
        self.stats.prefill_s += time.perf_counter() - t0
        return admitted

    def _prefill_group(self, reqs, w_bits: int, kv_bits: int, plen: int) -> None:
        cfg_g = self._group_cfg(kv_bits)
        cache = self.cache_for(kv_bits)
        max_len = cache.pages_for(plen) * self.page_size
        tokens = jnp.asarray(np.stack([r.feed_tokens() for r in reqs]))
        batch = {"tokens": tokens}
        if self.cfg.prefix_len:
            from repro.models.frontends import prefix_embeddings

            batch["prefix_emb"] = prefix_embeddings(self.cfg, len(reqs))
        logits, kv = self._prefill_fn(self.params_for(w_bits), batch, cfg_g, max_len)
        jax.block_until_ready(logits)
        # legacy one-shot prefill samples on the returned logits (still a
        # jitted op — ops.sample_tokens — just not fused into the prefill)
        samp = self._samp_arrays(reqs, len(reqs))
        if samp is None:
            first = np.asarray(jnp.argmax(logits, axis=-1))
        else:
            temps, top_ks, top_ps, seeds, positions = samp
            first = np.asarray(
                ops.sample_tokens(
                    logits, ops.sample_keys(seeds, positions),
                    temps, top_ks, top_ps,
                )
            )
        for i, req in enumerate(reqs):
            cache.allocate(req.rid, cache.pages_for(plen))
            if cache.quantized:
                cache.write_prompt(
                    req.rid, kv["k"][:, i], kv["v"][:, i],
                    kv["k_scale"][:, i], kv["v_scale"][:, i],
                )
            else:
                cache.write_prompt(req.rid, kv["k"][:, i], kv["v"][:, i])
            req.cache_len = plen
            self._on_prefill_done(req, int(first[i]))

    # ---------------------------------------------------------------- decode
    def _step_need(self, req: ServeRequest) -> int:
        """Cache positions this step may write for ``req``: the speculative
        window (drafts + the verify's bonus slot) for spec requests, one
        token otherwise."""
        if req.spec_k and req.out_tokens and not self._prefilling(req):
            remaining = req.max_new_tokens - len(req.out_tokens)
            return min(req.spec_k, max(remaining - 1, 0)) + 1
        return 1

    def _ensure_page_room(self) -> None:
        """Grow page tables for requests crossing a page boundary; preempt
        youngest-first when a pool is dry (oldest requests get pages first).
        The allocation path evicts LRU prefix-cache pages before preempting.
        Speculative requests ask for their whole verify window up front but
        *degrade to a plain-decode window* under pressure rather than evict
        anyone — speculation must never cost a batch-mate its pages."""
        for req in sorted(self._sched.running, key=lambda r: r.arrival):
            if req.state is not RequestState.RUNNING:
                continue
            cache = self.cache_for(req.kv_bits)
            need = self._step_need(req)
            while req.cache_len + need > cache.capacity_tokens(req.rid):
                if cache.can_allocate(1):
                    cache.extend(req.rid, 1)
                    continue
                if need > 1:
                    need = 1  # shrink the speculative window, keep decoding
                    continue
                victim = self._sched.pick_victim(kv_bits=req.kv_bits)
                self._preempt(victim)
                if victim is req:
                    break

    def _release_pages(self, req: ServeRequest) -> None:
        """Register materialized full blocks into the prefix cache, then drop
        the request's references (retained pages keep serving hits until the
        pool reclaims them)."""
        self._register_blocks(req)
        self.cache_for(req.kv_bits).free(req.rid)
        self._block_hashes.pop(req.rid, None)

    def _preempt(self, req: ServeRequest) -> None:
        self._release_pages(req)
        self._sched.preempt(req)
        self.stats.preemptions += 1

    def _finish(self, req: ServeRequest) -> None:
        # a stop token is always the stream's last token (decode finishes on
        # emission, spec windows are clipped right after it), so the reason
        # is readable off the tail; "stop" wins when the budget's final
        # token happens to be a stop token
        req.finish_reason = (
            FINISH_STOP
            if req.out_tokens and req.is_stop(req.out_tokens[-1])
            else FINISH_LENGTH
        )
        self._release_pages(req)
        self._sched.finish(req)
        self.finished.append(req)

    def _batch_arrays(self, cache: PagedKVCache, reqs: list[ServeRequest]):
        """pow2-bucketed (tokens, lengths, tables, valid) for a decode or
        spec group — padding rows are masked so they never touch the pool."""
        rids = [r.rid for r in reqs]
        width = max(len(cache.table(r)) for r in rids)
        width = bucket_pow2(width)  # pow2-bucket to limit retraces
        n_real = len(reqs)
        bsz = bucket_pow2(n_real)
        tables = np.zeros((bsz, width), np.int32)
        tables[:n_real] = cache.table_array(rids, width)
        tokens = np.zeros((bsz, 1), np.int32)
        tokens[:n_real] = np.array([[r.out_tokens[-1]] for r in reqs], np.int32)
        lengths = np.zeros(bsz, np.int32)
        lengths[:n_real] = np.array([r.cache_len for r in reqs], np.int32)
        valid = np.arange(bsz) < n_real
        return tokens, lengths, tables, valid

    def _decode_groups(self) -> int:
        """One batched call per precision group: ``(w_bits, kv_bits)`` plain
        decode groups emit one token per request;
        ``(w_bits, draft_bits, kv_bits)`` speculative groups run one fused
        draft+verify round each (serve/spec_decode.py) and emit 1..spec_k+1
        tokens per request."""
        plain: dict[tuple[int, int], list[ServeRequest]] = {}
        spec: dict[tuple[int, int, int], list[ServeRequest]] = {}
        for req in self._sched.running:
            if (
                req.state is RequestState.RUNNING
                and req.out_tokens
                and not self._prefilling(req)
            ):
                if req.spec_k > 0:
                    spec.setdefault(req.spec_group_key, []).append(req)
                else:
                    plain.setdefault(req.group_key, []).append(req)
        t0 = time.perf_counter()
        for (w_bits, kv_bits), reqs in sorted(plain.items()):
            self._plain_decode_group(reqs, w_bits, kv_bits)
        for (w_bits, draft_bits, kv_bits), reqs in sorted(spec.items()):
            self._spec_decode_group(reqs, w_bits, draft_bits, kv_bits)
        self.stats.decode_s += time.perf_counter() - t0
        n_groups = len(plain) + len(spec)
        if n_groups >= 2:
            self.stats.mixed_precision_steps += 1
        return n_groups

    def _plain_decode_group(
        self, reqs: list[ServeRequest], w_bits: int, kv_bits: int
    ) -> None:
        reqs.sort(key=lambda r: r.arrival)
        cache = self.cache_for(kv_bits)
        cfg_g = self._group_cfg(kv_bits)
        n_real = len(reqs)
        tokens, lengths, tables, valid = self._batch_arrays(cache, reqs)
        t_call = time.perf_counter()
        sampled, new_pools = self._decode_fn(
            self.params_for(w_bits), jnp.asarray(tokens),
            jnp.asarray(lengths), jnp.asarray(tables), jnp.asarray(valid),
            self._samp_arrays(reqs, len(valid)),
            cache.k, cache.v, cache.k_scale, cache.v_scale, cfg=cfg_g,
        )
        jax.block_until_ready(sampled)
        self.stats.decode_call_s.append(time.perf_counter() - t_call)
        cache.set_pools(*new_pools)  # new tokens scattered in-kernel
        next_tok = np.asarray(sampled[:n_real])
        for i, req in enumerate(reqs):
            req.cache_len += 1
            tok = int(next_tok[i])
            req.out_tokens.append(tok)
            self.stats.tokens_out += 1
            if req.is_stop(tok) or len(req.out_tokens) >= req.max_new_tokens:
                self._finish(req)
        self.stats.decode_steps += 1
        self.stats.occupancy_sum += len(reqs)
        key = (w_bits, kv_bits)
        self.stats.group_calls[key] = self.stats.group_calls.get(key, 0) + 1

    def _spec_decode_group(
        self, reqs: list[ServeRequest], w_bits: int, draft_bits: int,
        kv_bits: int,
    ) -> None:
        """One fused speculative round for a same-precision group: draft
        ``spec_k`` tokens at ``draft_bits``, verify the window at ``w_bits``
        under rejection sampling (exact equality for greedy rows), emit the
        accepted prefix + the resample/bonus token, then roll rejected tail
        pages back to the pool."""
        reqs.sort(key=lambda r: r.arrival)
        cache = self.cache_for(kv_bits)
        cfg_g = self._group_cfg(kv_bits)
        spec_k = max(r.spec_k for r in reqs)
        capacities = np.array(
            [cache.capacity_tokens(r.rid) for r in reqs], np.int64
        )
        n_draft = plan_windows(reqs, capacities, spec_k)
        if not n_draft.any():
            # every row's window degenerated to one token (final-token
            # budget or page pressure): a plain decode call does the same
            # job without spec_k masked-out draft passes + a verify chunk
            self._plain_decode_group(reqs, w_bits, kv_bits)
            return
        n_real = len(reqs)
        tokens, lengths, tables, valid = self._batch_arrays(cache, reqs)
        nd = np.zeros(len(valid), np.int32)
        nd[:n_real] = n_draft
        t_call = time.perf_counter()
        emit, accept, new_pools = self._spec_fn(
            self.params_for(draft_bits), self.params_for(w_bits),
            jnp.asarray(tokens), jnp.asarray(lengths), jnp.asarray(tables),
            jnp.asarray(valid), jnp.asarray(nd),
            self._samp_arrays(reqs, len(valid)),
            cache.k, cache.v, cache.k_scale, cache.v_scale,
            cfg=cfg_g, spec_k=spec_k,
        )
        jax.block_until_ready(emit)
        self.stats.decode_call_s.append(time.perf_counter() - t_call)
        cache.set_pools(*new_pools)  # draft K/V overwritten by verify K/V
        emit_np = np.asarray(emit)
        accept_np = np.asarray(accept)
        for i, req in enumerate(reqs):
            n_acc = int(accept_np[i])
            emitted = [int(t) for t in emit_np[i, : n_acc + 1]]
            emitted, stopped = clip_stop(req, emitted)
            req.out_tokens.extend(emitted)
            req.cache_len += len(emitted)
            self.stats.tokens_out += len(emitted)
            self.stats.spec_draft_tokens += int(n_draft[i])
            req.spec_drafted += int(n_draft[i])
            # count only accepted drafts the request actually used: a
            # mid-window stop token discards the accepted tail, and an
            # accept rate the emission didn't cash in would overstate the
            # CI-gated metric on eos-heavy workloads
            used_acc = min(len(emitted) - 1, n_acc)
            self.stats.spec_accepted_tokens += used_acc
            req.spec_accepted += used_acc
            # rollback: drop pages holding only rejected-window positions
            self._truncate_tail(req)
            if stopped or len(req.out_tokens) >= req.max_new_tokens:
                self._finish(req)
        self.stats.spec_rounds += 1
        self.stats.decode_steps += 1
        self.stats.occupancy_sum += len(reqs)
        key = (w_bits, kv_bits)
        self.stats.group_calls[key] = self.stats.group_calls.get(key, 0) + 1

    def _truncate_tail(self, req: ServeRequest) -> None:
        """Return table pages past ``req.cache_len`` to the pool.  Any
        prefix-cache entry for a dropped page is forgotten first: the verify
        window may have overwritten the page with rejected-token K/V, so it
        must not keep serving hits (registered blocks always precede the
        round's window, so in practice only defensively)."""
        cache = self.cache_for(req.kv_bits)
        keep = cache.pages_for(req.cache_len)
        tail = cache.table(req.rid)[keep:]
        if not tail:
            return
        pc = self._prefix.get(req.kv_bits)
        if pc is not None:
            pc.forget_pages(tail)
        cache.truncate(req.rid, req.cache_len)

    def step(self) -> bool:
        """One engine iteration; returns True if any work was done (failing
        an inadmissible request counts — it empties the queue)."""
        failed_before = self.stats.failed
        if self._legacy_prefill:
            admitted = self._admit_and_prefill()
            worked = bool(admitted)
        else:
            admitted = self._sched.admit(self._try_admit)
            pumping = any(
                r.state is RequestState.RUNNING and self._prefilling(r)
                for r in self._sched.running
            )
            self._prefill_pump()
            worked = bool(admitted) or pumping
        self._ensure_page_room()
        n_groups = self._decode_groups()
        self.stats.engine_steps += 1
        return worked or n_groups > 0 or self.stats.failed > failed_before

    def run(self) -> list[ServeRequest]:
        """Drive until every submitted request finishes or fails; returns
        them (completion order — check ``req.failed``/``req.error`` for
        requests rejected at admission)."""
        while self._sched.has_work():
            if not self.step():
                raise RuntimeError(
                    "engine stalled: no request can be admitted (free pages: "
                    f"{ {b: c.num_allocatable for b, c in self._caches.items()} })"
                )
        return self.finished

    def generate(
        self,
        requests: Optional[Iterable] = None,
    ) -> Iterator[Union[StreamEvent, GenerationOutput]]:
        """Streaming generation: drive the engine and yield one
        ``StreamEvent`` per emitted token, then the terminal
        ``GenerationOutput`` of each request as it finishes — callers no
        longer hand-roll the ``step()`` loop.

        ``requests`` may mix already-submitted ``ServeRequest`` handles with
        ``(prompt, sampling[, precision])`` tuples or bare prompts, which
        are submitted here; ``None`` streams everything currently enqueued.
        Tokens are yielded in emission order the moment the engine step that
        produced them completes, so a consumer streams one request's tokens
        while its batch-mates are still decoding.  Events are append-only
        across preemptions (recompute replays cache state, never emissions).
        """
        if requests is None:
            track = [*self._sched.waiting, *self._sched.running]
        else:
            track = []
            for r in requests:
                if isinstance(r, ServeRequest):
                    track.append(r)
                elif isinstance(r, (tuple, list)):
                    track.append(self.submit(*r))
                else:
                    track.append(self.submit(r))
        streamed = {r.rid: 0 for r in track}
        pending = {r.rid for r in track}

        def drain(req: ServeRequest):
            terminal = req.done or req.failed
            while streamed[req.rid] < len(req.out_tokens):
                i = streamed[req.rid]
                streamed[req.rid] = i + 1
                last = terminal and streamed[req.rid] == len(req.out_tokens)
                yield StreamEvent(
                    rid=req.rid,
                    token=req.out_tokens[i],
                    index=i,
                    finish_reason=req.finish_reason if last else None,
                )
            if terminal:
                pending.discard(req.rid)
                yield GenerationOutput.from_request(req)

        # anything already emitted before generate() was called (e.g. a
        # handle from a partially-driven engine) streams out first
        for req in track:
            yield from drain(req)
        while pending:
            if not self.step():
                raise RuntimeError(
                    "engine stalled: no request can be admitted (free pages: "
                    f"{ {b: c.num_allocatable for b, c in self._caches.items()} })"
                )
            for req in track:
                if req.rid in pending:
                    yield from drain(req)
