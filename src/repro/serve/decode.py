"""Jit'd ragged batched decode step over a paged KV cache.

One call decodes one token for every request in a same-precision group.  The
group's page tables are gathered into a contiguous [L, B, S, Hkv, D] view
(S = table_width * page_size), the new token's K/V is inserted at each
request's own position, and attention runs through
``models.attention.decode_attention`` — the same per-row-length contract the
Pallas ``mqa_decode`` kernel implements on real TPUs.  All weight matmuls go
through ``models.layers.dense``, which dispatches quantized weights to the
``mpmm`` multi-precision kernel path, so a W4A16 group and a W8A16 group
each cost one batched kernel call per projection per layer.

Unlike ``models.transformer.decode_step`` (one shared scalar position), every
row carries its own cache length — requests that joined the batch at
different times decode together.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import transformer as model_lib
from repro.models.layers import apply_rope, dense, rms_norm


def _gather_pages(pool: jnp.ndarray, tables: jnp.ndarray) -> jnp.ndarray:
    """[L, P, ps, ...] pool + [B, W] page tables -> [L, B, W*ps, ...]."""
    g = pool[:, tables]  # [L, B, W, ps, ...]
    l, b, w, ps = g.shape[:4]
    return g.reshape(l, b, w * ps, *g.shape[4:])


def paged_decode_step(
    params,
    tokens: jnp.ndarray,  # [B, 1] int32 — last generated token per request
    lengths: jnp.ndarray,  # [B] int32 — tokens already in cache (new token's position)
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    pool_k: jnp.ndarray,  # [L, P, ps, Hkv, D]
    pool_v: jnp.ndarray,
    pool_ks,  # [L, P, ps, Hkv, 1] f32 or None (kv_bits == 16)
    pool_vs,
    *,
    cfg: ArchConfig,
    mesh=None,
):
    """Returns (logits [B, V], new_kv) where new_kv is the new token's
    per-layer K/V (k, v[, k_scale, v_scale]) with k/v [L, B, Hkv, D] — the
    caller scatters it into the page pool.

    Not jit'd here: the engine jits a closure over its mesh (mesh objects
    aren't hashable jit statics), mirroring how it wraps prefill."""
    quant = cfg.serve_kv_bits < 16
    b = tokens.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]  # [B, 1, D]
    posv = lengths[:, None]  # [B, 1] per-row positions
    rows = jnp.arange(b)

    ck_all = _gather_pages(pool_k, tables)
    cv_all = _gather_pages(pool_v, tables)
    if quant:
        cks_all = _gather_pages(pool_ks, tables)
        cvs_all = _gather_pages(pool_vs, tables)

    windows = model_lib._per_layer_window(cfg, cfg.n_layers)

    def layer(carry, xs):
        x = carry
        p = xs["p"]
        win = xs["win"] if windows is not None else (cfg.window if cfg.window else None)
        xn = rms_norm(x, p["norm1"].astype(x.dtype), cfg.norm_eps)
        q = dense(xn, p["wq"]).reshape(b, 1, h, hd)
        k = dense(xn, p["wk"]).reshape(b, 1, hkv, hd)
        v = dense(xn, p["wv"]).reshape(b, 1, hkv, hd)
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
        if quant:
            kq, ksc = model_lib._quantize_token_kv(k, cfg.serve_kv_bits)
            vq, vsc = model_lib._quantize_token_kv(v, cfg.serve_kv_bits)
            ck = xs["k"].at[rows, lengths].set(kq[:, 0])
            cv = xs["v"].at[rows, lengths].set(vq[:, 0])
            cks = xs["ks"].at[rows, lengths].set(ksc[:, 0])
            cvs = xs["vs"].at[rows, lengths].set(vsc[:, 0])
            o = attn_mod.decode_attention(
                q, ck, cv, lengths + 1, window=win, k_scale=cks, v_scale=cvs
            )
            new_kv = (kq[:, 0], vq[:, 0], ksc[:, 0], vsc[:, 0])
        else:
            ck = xs["k"].at[rows, lengths].set(k[:, 0].astype(xs["k"].dtype))
            cv = xs["v"].at[rows, lengths].set(v[:, 0].astype(xs["v"].dtype))
            o = attn_mod.decode_attention(q, ck, cv, lengths + 1, window=win)
            new_kv = (k[:, 0], v[:, 0])
        x = x + dense(o.reshape(b, 1, h * hd), p["wo"])
        if cfg.family == "moe":
            m, _ = model_lib._moe_block(p, x, cfg, mesh)
            x = x + m
        else:
            x = x + model_lib._mlp_block(p, x, cfg)
        return x, new_kv

    xs = {"p": params["blocks"], "k": ck_all, "v": cv_all}
    if quant:
        xs["ks"] = cks_all
        xs["vs"] = cvs_all
    if windows is not None:
        xs["win"] = windows
    x, new_kv = jax.lax.scan(layer, x, xs)

    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = dense(x[:, -1], params["unembed"]).astype(jnp.float32)
    logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e30)
    return logits, new_kv
