"""Jit'd ragged batched decode step straight over the paged KV pool.

One call decodes one token for every request in a same-precision group.
Attention never materializes a contiguous cache view: each layer calls
``models.attention.paged_decode_attention``, which walks the group's page
tables inside the kernel (Pallas on TPU, slot-scan XLA fallback elsewhere)
and reads only the pages holding each row's ``lengths[b]`` cached tokens.
The token being decoded enters the online softmax as a fused extra term, and
after the layer scan its quantized K/V is scattered *directly* into its page
(``pool.at[:, page, off].set``) — the old gather → insert → re-scatter
round-trip through a ``[L, B, S, Hkv, D]`` view is gone, so per-token
attention traffic is proportional to actual cache lengths, not
``L x B x table_capacity``.

All weight matmuls go through ``models.layers.dense``, which dispatches
quantized weights to the ``mpmm`` multi-precision kernel path, so a W4A16
group and a W8A16 group each cost one batched kernel call per projection per
layer.  Unlike ``models.transformer.decode_step`` (one shared scalar
position), every row carries its own cache length — requests that joined the
batch at different times decode together.  Rows with ``valid[b] == False``
are pow2-bucket padding: they compute garbage logits (sliced off by the
engine) and their append is dropped via an out-of-range page id.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import attention as attn_mod
from repro.models import transformer as model_lib
from repro.models.layers import apply_rope, dense, rms_norm


def paged_decode_step(
    params,
    tokens: jnp.ndarray,  # [B, 1] int32 — last generated token per request
    lengths: jnp.ndarray,  # [B] int32 — tokens already in cache (new token's position)
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    valid: jnp.ndarray,  # [B] bool — False for pow2-bucket padding rows
    pool_k: jnp.ndarray,  # [L, P, ps, Hkv, D]
    pool_v: jnp.ndarray,
    pool_ks,  # [L, P, ps, Hkv, 1] f32 or None (kv_bits == 16)
    pool_vs,
    *,
    cfg: ArchConfig,
    mesh=None,
):
    """Returns (logits [B, V], new_pools) where new_pools is the page pool
    with every valid row's new token already scattered into its page —
    (k, v, k_scale, v_scale), scales None when kv_bits == 16.  The caller
    adopts the returned pools (donation makes the scatter in-place).

    Append precondition: every row with valid[b] == True must have a table
    slot allocated for position lengths[b] (lengths[b] < table_len * ps —
    the engine guarantees this via _ensure_page_room).  Zero-padded table
    entries are indistinguishable from a real page 0, so a row whose *own*
    table is exhausted inside a wider padded table would scatter into page 0;
    set valid[b] = False for any row that must not append.  Appends at or
    past the padded width W are dropped automatically.

    Not jit'd here: the engine jits a closure over its mesh (mesh objects
    aren't hashable jit statics), mirroring how it wraps prefill."""
    quant = cfg.serve_kv_bits < 16
    b = tokens.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    n_layers = pool_k.shape[0]
    num_pages, page_size = pool_k.shape[1], pool_k.shape[2]
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]  # [B, 1, D]
    posv = lengths[:, None]  # [B, 1] per-row positions
    rows = jnp.arange(b)

    windows = model_lib._per_layer_window(cfg, cfg.n_layers)

    def layer(carry, xs):
        x = carry
        p, li = xs["p"], xs["li"]
        win = xs["win"] if windows is not None else (cfg.window if cfg.window else None)
        xn = rms_norm(x, p["norm1"].astype(x.dtype), cfg.norm_eps)
        q = dense(xn, p["wq"]).reshape(b, 1, h, hd)
        k = dense(xn, p["wk"]).reshape(b, 1, hkv, hd)
        v = dense(xn, p["wv"]).reshape(b, 1, hkv, hd)
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
        if quant:
            kq, ksc = model_lib._quantize_token_kv(k, cfg.serve_kv_bits)
            vq, vsc = model_lib._quantize_token_kv(v, cfg.serve_kv_bits)
            o = attn_mod.paged_decode_attention(
                q, pool_k, pool_v, tables, lengths, li, kq[:, 0], vq[:, 0],
                window=win, k_scale=pool_ks, v_scale=pool_vs,
                new_k_scale=ksc[:, 0], new_v_scale=vsc[:, 0],
                kv_bits=cfg.serve_kv_bits,
            )
            new_kv = (kq[:, 0], vq[:, 0], ksc[:, 0], vsc[:, 0])
        else:
            kc = k[:, 0].astype(pool_k.dtype)
            vc = v[:, 0].astype(pool_v.dtype)
            o = attn_mod.paged_decode_attention(
                q, pool_k, pool_v, tables, lengths, li, kc, vc,
                window=win, kv_bits=cfg.serve_kv_bits,
            )
            new_kv = (kc, vc)
        x = x + dense(o.reshape(b, 1, h * hd), p["wo"])
        if cfg.family == "moe":
            m, _ = model_lib._moe_block(p, x, cfg, mesh)
            x = x + m
        else:
            x = x + model_lib._mlp_block(p, x, cfg)
        return x, new_kv

    xs = {"p": params["blocks"], "li": jnp.arange(n_layers, dtype=jnp.int32)}
    if windows is not None:
        xs["win"] = windows
    x, new_kv = jax.lax.scan(layer, x, xs)

    # Fused token append: scatter each row's new K/V straight into its page.
    # Padding rows get an out-of-range page id, which jax scatters drop; a
    # slot index at/past the padded width W must fill out-of-range too, not
    # clamp to the last entry and overwrite it.  (A row whose own shorter
    # table is exhausted *inside* W is the caller's job to mask via `valid`
    # — see the append precondition in the docstring.)
    page_ids = tables.at[rows, lengths // page_size].get(
        mode="fill", fill_value=num_pages
    )
    page_ids = jnp.where(valid, page_ids, num_pages)
    offs = lengths % page_size

    def scatter(pool, new):
        return pool.at[:, page_ids, offs].set(new.astype(pool.dtype), mode="drop")

    if quant:
        new_k, new_v, new_ks, new_vs = new_kv
        pools = (
            scatter(pool_k, new_k),
            scatter(pool_v, new_v),
            scatter(pool_ks, new_ks),
            scatter(pool_vs, new_vs),
        )
    else:
        new_k, new_v = new_kv
        pools = (scatter(pool_k, new_k), scatter(pool_v, new_v), None, None)

    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = dense(x[:, -1], params["unembed"]).astype(jnp.float32)
    logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e30)
    return logits, pools


def paged_decode_sample(
    params,
    tokens: jnp.ndarray,  # [B, 1] int32 — last generated token per request
    lengths: jnp.ndarray,  # [B] int32 — tokens already in cache
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    valid: jnp.ndarray,  # [B] bool — False for pow2-bucket padding rows
    samp,  # (temperature [B], top_k [B], top_p [B], seed [B], position [B])
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    pool_ks,
    pool_vs,
    *,
    cfg: ArchConfig,
    mesh=None,
):
    """One decode step *and* the next-token choice, fused in one jitted
    graph: runs :func:`paged_decode_step`, then draws each row's next token
    with its own (temperature, top_k, top_p) under the position-keyed PRNG
    (``kernels/ops.py::sample_tokens``; greedy rows are exact argmax).
    ``samp is None`` means the whole group is greedy — the graph is the bare
    argmax, identical to the pre-sampling engine, paying zero sampling
    compute; ``top_k``/``top_p`` may likewise be None inside the tuple when
    no row in the group uses them (the mask sorts are elided statically).
    Returns (next_tokens [B] int32, new_pools)."""
    logits, pools = paged_decode_step(
        params, tokens, lengths, tables, valid,
        pool_k, pool_v, pool_ks, pool_vs, cfg=cfg, mesh=mesh,
    )
    if samp is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), pools
    temps, top_ks, top_ps, seeds, positions = samp
    keys = ops.sample_keys(seeds, positions)
    return ops.sample_tokens(logits, keys, temps, top_ks, top_ps), pools
