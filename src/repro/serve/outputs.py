"""Streaming output types for the generation API.

``ServeEngine.generate()`` yields one ``StreamEvent`` per emitted token, in
emission order, the moment the engine step that produced it completes —
callers stream tokens out while batch-mates are still decoding.  When a
request finishes (budget, stop token, or admission failure), its terminal
``GenerationOutput`` follows, carrying the whole stream plus the request's
latency/preemption/speculation accounting.

Events are append-only: preemption replays *compute* (the KV cache is
rebuilt) but never un-emits a token, so a consumer may act on every event as
it arrives.  Finish reasons:

* ``"stop"``   — the request emitted its ``eos_id`` or a ``stop_tokens``
  member (the stop token is the last token of the stream).
* ``"length"`` — the ``max_new_tokens`` budget is spent.
* ``"failed"`` — rejected at admission (e.g. the context can never fit the
  page pool); ``GenerationOutput.error`` says why and the stream is empty.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

FINISH_STOP = "stop"
FINISH_LENGTH = "length"
FINISH_FAILED = "failed"


@dataclass(frozen=True)
class StreamEvent:
    """One emitted token of one request's stream.

    ``index`` is the token's position in the output text (0 = first
    generated token); ``finish_reason`` is None mid-stream and set on the
    stream's final event."""

    rid: int
    token: int
    index: int
    finish_reason: Optional[str] = None

    @property
    def is_last(self) -> bool:
        return self.finish_reason is not None


@dataclass(frozen=True)
class GenerationOutput:
    """Terminal summary of one request, yielded after its last StreamEvent.

    ``ttft``: submit -> first token, seconds (None if the request failed
    before emitting).  ``spec_drafted`` / ``spec_accepted``: this request's
    own speculative-decoding accounting (0/0 for plain-decode requests)."""

    rid: int
    tokens: tuple[int, ...]
    finish_reason: str
    error: Optional[str] = None
    ttft: Optional[float] = None
    preemptions: int = 0
    spec_drafted: int = 0
    spec_accepted: int = 0

    @property
    def spec_accept_rate(self) -> float:
        """Fraction of this request's drafted tokens the verify accepted."""
        return self.spec_accepted / max(self.spec_drafted, 1)

    @classmethod
    def from_request(cls, req) -> "GenerationOutput":
        """Build the terminal output for a FINISHED or FAILED ServeRequest."""
        return cls(
            rid=req.rid,
            tokens=tuple(req.out_tokens),
            finish_reason=req.finish_reason or (
                FINISH_FAILED if req.failed else FINISH_LENGTH
            ),
            error=req.error,
            ttft=req.ttft,
            preemptions=req.preemptions,
            spec_drafted=req.spec_drafted,
            spec_accepted=req.spec_accepted,
        )
