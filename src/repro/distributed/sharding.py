"""Sharding rules: parameter-name patterns -> PartitionSpec, activation
constraints, and the mesh context the model code consults.

Axis conventions (launch/mesh.py):
  single pod:  (data=16, model=16)            axes ("data", "model")
  multi-pod:   (pod=2, data=16, model=16)     axes ("pod", "data", "model")
The ``pod`` axis composes as outer data parallelism by default (optionally a
pipeline axis — distributed/pipeline.py).  Batch shards over BATCH_AXES =
("pod", "data") when present; tensor/expert parallelism over "model".

Model code calls :func:`shard` (activations) and the launcher materializes
parameter shardings from :func:`param_spec` (name-pattern rules).  When no
mesh context is active (unit tests, single device) everything degrades to
no-ops so the model runs unmodified on CPU.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_ACTIVE_MESH: Optional[Mesh] = None
_DATA_ONLY = False  # FSDP/ZeRO mapping: every mesh axis is a batch axis


def set_mesh(mesh: Optional[Mesh], data_only: bool = False) -> None:
    """Installs the mesh the model's activation constraints resolve against.

    data_only=True selects the FSDP/ZeRO-3 mapping: the batch shards over ALL
    mesh axes and no tensor parallelism is requested — weights stay 2-D
    sharded (the param rules) and XLA gathers them layer-by-layer, which for
    small-dense models replaces O(layers x activation) TP all-reduces with
    O(params) weight all-gathers (hillclimb #1 in EXPERIMENTS.md §Perf).
    """
    global _ACTIVE_MESH, _DATA_ONLY
    _ACTIVE_MESH = mesh
    _DATA_ONLY = data_only


def get_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def batch_axes() -> tuple[str, ...]:
    if _ACTIVE_MESH is None:
        return ()
    if _DATA_ONLY:
        return tuple(_ACTIVE_MESH.axis_names)
    return tuple(a for a in ("pod", "data") if a in _ACTIVE_MESH.axis_names)


def model_axis() -> Optional[str]:
    if _ACTIVE_MESH is None or _DATA_ONLY:
        return None
    if "model" in _ACTIVE_MESH.axis_names:
        return "model"
    return None


def gather_weight(w):
    """Under the FSDP/ZeRO-3 mapping, explicitly materialize the replicated
    weight from its shards BEFORE any dtype conversion: the all-gather then
    moves bf16/int8 payloads (not f32 converts — 2-4x wire savings), and the
    constraint's transpose turns weight-grad all-reduces into
    reduce-scatters to the param shards (§Perf hillclimb #1b)."""
    if _ACTIVE_MESH is None or not _DATA_ONLY:
        return w
    if isinstance(w, dict):  # quantized payload
        out = dict(w)
        for k in ("data", "scale"):
            if k in out:
                out[k] = jax.lax.with_sharding_constraint(
                    out[k], NamedSharding(_ACTIVE_MESH, P(*([None] * out[k].ndim)))
                )
        return out
    return jax.lax.with_sharding_constraint(
        w, NamedSharding(_ACTIVE_MESH, P(*([None] * w.ndim)))
    )


def shard(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint under the active mesh; no-op otherwise.

    spec entries: "batch" (expands to the batch axes tuple), "model", or None.
    """
    if _ACTIVE_MESH is None:
        return x
    resolved = []
    for s in spec:
        if s == "batch":
            ax = batch_axes()
            resolved.append(ax if ax else None)
        elif s == "model":
            resolved.append(model_axis())
        else:
            resolved.append(s)
    p = validate_spec(P(*resolved), x.shape, _ACTIVE_MESH)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_ACTIVE_MESH, p))


# --------------------------------------------------------------- parameters
# Pattern rules, first match wins; each pattern lists CANDIDATE specs and the
# first (after divisibility validation) with the largest sharding factor wins.
# Weights shard 2-D: tensor-parallel over "model" AND FSDP/ZeRO-style over
# "data" (grads + optimizer states inherit the same specs), which is what
# keeps 1e12-parameter states inside 16 GB/chip.  Stacked layer params get a
# leading None automatically.
_RULES: list[tuple[str, list[tuple] | None]] = [
    (r"(^|/)embed$", [("model", "data")]),  # [V, D]
    (r"unembed$", [("data", "model")]),  # [D, V]
    (r"(wq|wk|wv)$", [("data", "model")]),  # [D, H*hd]
    (r"wo$", [("model", "data")]),  # [H*hd, D]
    (r"router$", [(None, None)]),  # small, replicated
    # MoE experts [E, D, F] / [E, F, D]: experts over model when divisible,
    # otherwise fall back to sharding the matrix dims (mixtral has E=8 < 16)
    (r"moe/(wg|wu|wd)$", [("model", "data", None), (None, "data", "model")]),
    (r"mlp/(wg|wu)$", [("data", "model")]),  # [D, F]
    (r"mlp/wd$", [("model", "data")]),  # [F, D]
    (r"in_proj$", [("data", "model")]),  # ssm fused proj (d_inner sharded)
    (r"out_proj$", [("model", "data")]),
    (r"(A_log|D|dt_bias)$", [(None,)]),  # tiny per-head vectors: replicated
    (r"(norm|norm1|norm2|final_norm|gamma)$", [(None,)]),
    (r"(data|scale|bits)$", None),  # quantized leaves: rule resolved by parent
]


def _pad_spec(spec: tuple, ndim: int) -> tuple:
    if len(spec) < ndim:  # stacked layers: leading layer dims replicate
        return (None,) * (ndim - len(spec)) + spec
    if len(spec) > ndim:  # e.g. packed/quantized lost a dim: trim
        return spec[-ndim:] if ndim else ()
    return spec


def _shard_factor(spec: P, mesh: Mesh) -> int:
    f = 1
    for ax in spec:
        if ax is None:
            continue
        for a in (ax,) if isinstance(ax, str) else ax:
            f *= mesh.shape[a]
    return f


def param_spec(path: str, ndim: int, shape=None, mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for a parameter at `path`.  With shape+mesh, candidates
    are validated for divisibility and the most-sharded survivor wins."""
    for pat, candidates in _RULES:
        if candidates is None:
            continue
        if re.search(pat, path):
            specs = [P(*_pad_spec(tuple(c), ndim)) for c in candidates]
            if shape is None or mesh is None:
                return specs[0]
            validated = [validate_spec(s, shape, mesh) for s in specs]
            return max(validated, key=lambda s: _shard_factor(s, mesh))
    return P(*([None] * ndim))


def _iter_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_paths(v, f"{prefix}/{k}" if prefix else str(k))
    else:
        yield prefix, tree


def validate_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drops mesh axes from dims they don't divide (e.g. fused projections
    whose output dim is not a multiple of the model-parallel degree) and
    axes absent from the mesh (e.g. "pod" on a single-pod mesh)."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = tuple(
            a for a in ((ax,) if isinstance(ax, str) else tuple(ax))
            if a in mesh.axis_names
        )
        if not axes:
            out.append(None)
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        ax_out = axes[0] if len(axes) == 1 else axes
        out.append(ax_out if dim % size == 0 else None)
    return P(*out)


def tree_shardings(params, mesh: Mesh):
    """NamedSharding pytree matching `params` via the pattern rules."""

    def one(path: str, leaf):
        nd = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", None)
        # quantized dicts: the leaf names are data/scale/bits under the
        # original weight name — reuse the parent rule for `data`.
        if path.endswith(("/data", "/scale")):
            # parent rule; size-1 dims (the scale's reduced K axis) drop in
            # validation automatically
            spec = param_spec(path.rsplit("/", 1)[0], nd, shape, mesh)
        elif path.endswith("/bits"):
            spec = P()
        else:
            spec = param_spec(path, nd, shape, mesh)
        if shape is not None:
            spec = validate_spec(spec, shape, mesh)
        return NamedSharding(mesh, spec)

    paths = dict(_iter_paths(params))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)

    def path_str(kp):
        parts = []
        for e in kp:
            if hasattr(e, "key"):
                parts.append(str(e.key))
            elif hasattr(e, "idx"):
                parts.append(str(e.idx))
        return "/".join(parts)

    leaves = [one(path_str(kp), leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)
