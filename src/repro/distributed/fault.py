"""Fault tolerance & straggler mitigation for long-running jobs.

Synchronous SPMD on TPU pods has a specific failure model: a lost/slow host
stalls the whole job, so production resilience = (a) never lose more than a
bounded amount of work (checkpoint cadence + atomicity), (b) detect the
stall quickly (step-deadline watchdog), (c) restart on the surviving/replaced
topology (elastic reshard) and replay deterministically (data pipeline keyed
by step).  This module supplies (b) plus the retry/resume driver; (a) lives
in checkpoint/manager.py and (c) in distributed/elastic.py + the data
pipeline.

``StepMonitor`` tracks an EMA of step wall-time and flags steps exceeding
``deadline_factor`` x EMA — the straggler signal.  On real pods the runbook
reaction is: snapshot (async checkpoint), evict/replace the slow host, and
resume; here the reaction is pluggable (tests inject failures and assert the
driver resumes from the last checkpoint with identical results).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class StepMonitor:
    ema_decay: float = 0.9
    deadline_factor: float = 3.0
    warmup_steps: int = 3
    ema: Optional[float] = None
    steps_seen: int = 0
    stragglers: list[int] = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler (deadline exceeded)."""
        self.steps_seen += 1
        if self.ema is None:
            self.ema = seconds
            return False
        is_straggler = (
            self.steps_seen > self.warmup_steps
            and seconds > self.deadline_factor * self.ema
        )
        if is_straggler:
            self.stragglers.append(step)
        else:  # stragglers do not poison the EMA
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * seconds
        return is_straggler


def run_with_restarts(
    train_fn: Callable[[int], int],
    *,
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, Exception], None]] = None,
) -> int:
    """Crash-resilient driver: ``train_fn(start_step) -> final_step`` runs the
    loop from its last checkpoint; any exception triggers restore + retry
    (bounded).  Used by launch/train.py and the fault-injection tests."""
    restarts = 0
    start_step = 0
    while True:
        try:
            return train_fn(start_step)
        except Exception as e:  # noqa: BLE001 — deliberate: any step failure
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)
            # train_fn re-reads its checkpoint manager for the resume step
            start_step = -1  # sentinel: resume from latest checkpoint
            time.sleep(0.01)
