"""Distributed runtime: sharding rules, compressed collectives, pipeline
parallelism, elastic resharding, fault tolerance."""
