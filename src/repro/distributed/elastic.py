"""Elastic scaling: rebuild the mesh for whatever devices survive and reshard
the checkpointed state onto it.

The contract: training state is checkpointed host-gathered (checkpoint/
manager.py), the data pipeline is a pure function of (seed, step), and
parameter shardings are derived from name-pattern rules — so restoring onto
a DIFFERENT mesh shape is just `make_elastic_mesh(n_devices)` + restore with
the new shardings.  Nothing about the training state encodes the old
topology.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import tree_shardings


def make_elastic_mesh(n_devices: Optional[int] = None, model_parallel: int = 1) -> Mesh:
    """Largest (data, model) mesh fitting the available devices.  model_parallel
    must divide the device count; leftover devices are dropped (reported)."""
    devs = jax.devices()
    n = n_devices or len(devs)
    n = min(n, len(devs))
    if n % model_parallel:
        raise ValueError(f"{n} devices not divisible by model_parallel={model_parallel}")
    data = n // model_parallel
    return jax.make_mesh((data, model_parallel), ("data", "model"),
                         devices=devs[:n]) if hasattr(jax, "make_mesh") else Mesh(
        jax.numpy.array(devs[:n]).reshape(data, model_parallel), ("data", "model")
    )


def reshard_state(state, mesh: Mesh):
    """Places a host-side state pytree onto `mesh` under the standard rules."""
    sh = tree_shardings(state, mesh)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), state, sh)
