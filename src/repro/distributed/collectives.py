"""Distributed-optimization collectives.

int8 gradient compression with error feedback — the paper's multi-precision
idea applied to the data-parallel gradient reduction:

    1. residual-corrected gradient  g' = g + e   (error feedback state e)
    2. blockwise int8 quantize (per-chunk fp32 scales)
    3. reduce-scatter expressed as all_to_all of int8 chunks (bytes on the
       wire are 1/4 of fp32) + local fp32 reduction of the received chunks
    4. int8 all-gather of each shard's reduced chunk
    5. e <- g' - dequant(result)   (what compression lost, fed back next step)

Under shard_map over the data axis; the model axis (TP) gradients are exact
(XLA's own psum).  Convergence impact is bounded by the error-feedback
theorem (Karimireddy et al. 2019); tests assert byte counts and allclose-
with-tolerance vs the exact psum.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

_CHUNK = 1024


def _quantize_chunks(x: jnp.ndarray, n_shards: int):
    """flat fp32 [n] -> (int8 [n_shards, m], scales [n_shards, m//CHUNK, 1])."""
    n = x.shape[0]
    per = -(-n // n_shards)
    per = per + (-per) % _CHUNK
    xp = jnp.pad(x, (0, n_shards * per - n)).reshape(n_shards, per // _CHUNK, _CHUNK)
    scale = jnp.maximum(jnp.max(jnp.abs(xp), axis=-1, keepdims=True), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_chunks(q: jnp.ndarray, scale: jnp.ndarray, n: int) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


def compressed_psum_mean(
    x: jnp.ndarray, axis: str, e2: jnp.ndarray | None = None
) -> jnp.ndarray | tuple[jnp.ndarray, jnp.ndarray]:
    """Mean-reduce `x` over mesh axis `axis` with int8 wire traffic.

    Call INSIDE shard_map.  Implements reduce-scatter (all_to_all of int8
    chunks + local fp32 sum) followed by an int8 all-gather.

    ``e2`` is the error-feedback state of the SECOND quantization stage (the
    owner shard's reduced chunk): pass the previous call's returned residual
    and both stages telescope — the cumulative reduced sum then deviates from
    the exact sum by at most one quantization step, not O(T) (see
    tests/test_collectives.py).  With e2 given, returns (mean, e1_residual,
    e2_residual): add e1_residual to next round's x.  When e2 is None only
    the value is returned (residuals dropped; fine for one-shot reductions).
    """
    # jax.lax.axis_size only exists in newer jax; psum(1) is the portable form
    n_shards = jax.lax.psum(1, axis)
    shape, n = x.shape, x.size
    flat = x.reshape(-1).astype(jnp.float32)
    q, scale = _quantize_chunks(flat, n_shards)  # [S, m/C, C] int8
    # stage-1 residual: what MY local quantization lost (the EF state the
    # caller must add back next round — NOT x minus the final mean)
    e1_new = (flat - _dequantize_chunks(q, scale, n)).reshape(shape)
    # reduce-scatter: shard i collects chunk i from every peer
    q_t = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0, tiled=False)
    s_t = jax.lax.all_to_all(scale, axis, split_axis=0, concat_axis=0, tiled=False)
    # local fp32 reduction of my chunk across peers
    mine = jnp.sum(q_t.astype(jnp.float32) * s_t, axis=0) / n_shards  # [m/C, C]
    if e2 is not None:
        mine = mine + e2
    # re-quantize my reduced chunk and all-gather int8
    sc2 = jnp.maximum(jnp.max(jnp.abs(mine), axis=-1, keepdims=True), 1e-30) / 127.0
    q2 = jnp.clip(jnp.round(mine / sc2), -127, 127).astype(jnp.int8)
    e2_new = mine - q2.astype(jnp.float32) * sc2
    qg = jax.lax.all_gather(q2, axis, axis=0, tiled=False)  # [S, m/C, C]
    sg = jax.lax.all_gather(sc2.astype(jnp.float32), axis, axis=0, tiled=False)
    red = _dequantize_chunks(qg, sg, n).reshape(shape)
    if e2 is not None:
        return red, e1_new, e2_new
    return red


def compressed_grad_reduce(
    grads: Any,
    error: Any,
    mesh: Mesh,
    data_axes: tuple[str, ...] = ("data",),
) -> tuple[Any, Any]:
    """Error-feedback int8 mean-reduction of a gradient pytree over the data
    axes.  grads are per-shard (unreduced); returns (reduced grads, new error
    state).  Leaves smaller than one chunk reduce exactly (fp32 psum)."""
    axis = data_axes[0] if len(data_axes) == 1 else data_axes

    def local(g_tree, e_tree):
        def one(g, e):
            e1, e2 = e["e1"], e["e2"]
            gf = g.astype(jnp.float32) + e1
            if g.size < _CHUNK:  # tiny leaves: exact
                red = jax.lax.pmean(gf, axis)
                return red.astype(g.dtype), {"e1": jnp.zeros_like(gf), "e2": e2}
            red, e1n, e2n = compressed_psum_mean(
                gf, axis if isinstance(axis, str) else axis[0], e2
            )
            return red.astype(g.dtype), {"e1": e1n, "e2": e2n}

        flat_g, tdef = jax.tree_util.tree_flatten(g_tree)
        flat_e = tdef.flatten_up_to(e_tree)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (
            jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs]),
        )

    spec = jax.tree.map(lambda _: P(), grads)  # grads replicated per data shard
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec),
        check_rep=False,
    )(grads, error)


def init_error_state(grads_proto: Any, n_shards: int = 1) -> Any:
    def one(g):
        per = -(-g.size // n_shards)
        per = per + (-per) % _CHUNK
        return {
            "e1": jnp.zeros(g.shape, jnp.float32),
            "e2": jnp.zeros((per // _CHUNK, _CHUNK), jnp.float32),
        }
    return jax.tree.map(one, grads_proto)
