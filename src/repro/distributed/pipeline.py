"""Pipeline parallelism over the ``pod`` mesh axis (GPipe-style).

The production mesh exposes ``pod`` as an outer axis; by default it composes
as data parallelism, but cross-pod data-parallel gradient sync moves every
parameter every step over the slower inter-pod links.  For deep models an
alternative is to place CONSECUTIVE LAYER STAGES on pods and stream
microbatches through with jax.lax.ppermute — inter-pod traffic becomes
activations (B_micro x S x D per step boundary), often orders of magnitude
smaller than the parameter gradients.

Implementation: shard_map over ('pod',); each pod holds its stage's stacked
layer params ([L/pods, ...]).  The classic loop runs n_micro + n_stages - 1
ticks; at each tick a stage processes the microbatch it received last tick
and ppermutes its output forward.  Bubble fraction = (S-1)/(M+S-1).

This module implements *inference/forward* pipelining generically (any
per-stage apply function) plus a pipelined train-forward used by the tests
to verify exactness vs the unpipelined reference; integrating full pipelined
backward into the main trainer is intentionally left switchable (the dry-run
meshes default to pod=DP) — see DESIGN.md 'Distribution design'.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def pipeline_apply(
    stage_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
    stage_params: jnp.ndarray,  # pytree, leading dim = n_stages (sharded on pod)
    x: jnp.ndarray,  # [n_micro, B_micro, ...] microbatched input
    mesh: Mesh,
    axis: str = "pod",
):
    """Runs x through n_stages sequential stages, pipelined over `axis`.

    stage_fn(params_for_stage, microbatch) -> microbatch (same shape).
    Returns [n_micro, B_micro, ...] outputs (as produced by the LAST stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    def local(params_stage, xs):
        # params_stage: this pod's stage params (leading stage dim squeezed)
        # xs: this pod's copy of ALL microbatches (replicated input)
        stage = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params_stage)

        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])  # the microbatch currently held
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t; others use what arrived last tick
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(stage == 0, xs[inject], buf)
            y = stage_fn(p, x_in)
            # collect finished microbatches at the last stage:
            m_idx = t - (n_stages - 1)
            is_out = (stage == n_stages - 1) & (m_idx >= 0)
            outs = jax.lax.cond(
                is_out,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(m_idx, 0), 0
                ),
                lambda o: o,
                outs,
            )
            # forward y to the next stage
            buf_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return (buf_next, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # only the last stage holds real outputs (zeros elsewhere): a psum
        # broadcasts them to every pod (ppermute requires unique sources)
        outs = jax.lax.psum(outs, axis) if n_stages > 1 else outs
        return outs

    params_spec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(params_spec, P()),
        out_specs=P(),
        check_rep=False,
    )(stage_params, x)
