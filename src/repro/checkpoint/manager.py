"""Checkpointing: sharded, atomic, async, restorable onto a different mesh.

Layout per step:

    <dir>/step_000123.tmp/          (written first)
        manifest.json               step, config digest, mesh shape, tree spec
        arr_00000.npy ...           one file per leaf (host-gathered)
    <dir>/step_000123/              (atomic rename when complete)

Design points for 1000+ nodes:
  * **atomicity** — a checkpoint is visible iff its final rename happened;
    crashed writers leave only ``.tmp`` dirs which restore ignores and
    cleanup prunes.  No torn checkpoints.
  * **async** — ``save(..., blocking=False)`` snapshots to host memory
    (device_get) and writes on a background thread; the train loop loses
    only the device->host copy time.
  * **keep-N** — bounded disk usage.
  * **elastic restore** — arrays are saved unsharded (host-gathered);
    ``restore(target=...)`` device_puts onto the CURRENT mesh's shardings,
    so a job can restart on a different pod count / mesh shape
    (tested by tests/test_checkpoint.py::test_elastic_reshard).

On a real multi-host pod each host would write its addressable shards
(process-local npy per shard index) — the single-process container here
exercises the full protocol with host-gathered arrays; the manifest already
records mesh/sharding metadata to support the per-shard layout.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------- helpers --
    def _path(self, step: int, tmp: bool = False) -> str:
        return os.path.join(self.dir, f"step_{step:09d}" + (".tmp" if tmp else ""))

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -------------------------------------------------------------- save --
    def save(self, step: int, tree: Any, meta: Optional[dict] = None, blocking: bool = True) -> None:
        # snapshot to host synchronously (cheap relative to the write)
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

        def write():
            tmp = self._path(step, tmp=True)
            final = self._path(step)
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            dtypes = []
            for i, arr in enumerate(host_leaves):
                dtypes.append(str(arr.dtype))
                # ml_dtypes (bfloat16 etc.) round-trip as raw views over a
                # byte-compatible numpy dtype
                save_arr = arr.view(np.uint16) if arr.dtype.str == "<V2" or str(arr.dtype) == "bfloat16" else arr
                np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), save_arr)
            manifest = {
                "step": step,
                "dtypes": dtypes,
                "n_leaves": len(host_leaves),
                "treedef": str(treedef),
                "digest": hashlib.sha256(
                    "".join(f"{a.shape}{a.dtype}" for a in host_leaves).encode()
                ).hexdigest(),
                "meta": meta or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
            self._gc()

        self.wait()
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)
        for name in os.listdir(self.dir):  # orphaned tmp dirs from crashes
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # ----------------------------------------------------------- restore --
    def restore(self, step: Optional[int] = None, target: Any = None, shardings: Any = None):
        """Loads step (default latest).  ``target``: a pytree prototype
        (treedef source).  ``shardings``: optional matching pytree of
        NamedSharding for elastic placement onto the current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        dtypes = manifest.get("dtypes")
        arrs = []
        for i in range(manifest["n_leaves"]):
            a = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
            if dtypes is not None:
                want = dtypes[i]
                if str(a.dtype) != want:
                    import ml_dtypes

                    a = a.view(np.dtype(getattr(ml_dtypes, want, want)))
            arrs.append(a)
        if target is None:
            return arrs, manifest
        treedef = jax.tree_util.tree_structure(target)
        tree = jax.tree_util.tree_unflatten(treedef, arrs)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        return tree, manifest
