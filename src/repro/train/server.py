"""Synchronous serving wrapper over the continuous-batching engine.

Historically this module WAS the serving engine (static waves of
``batch_size`` requests).  The engine proper now lives in ``repro.serve``
— per-request weight/KV precision, paged KV cache, FCFS admission with
preemption, same-precision kernel-call grouping — and this module keeps the
small blocking API the launcher, examples and tests were built on: construct
a ``Server``, hand it a list of ``Request``s, get them back completed.

Architectures the paged engine can't host (ssm / hybrid recurrent caches,
MoE with leading dense blocks — see ``ServeEngine.supports``) fall back to
the original static-wave scheduler over ``models.transformer``'s prefill /
decode_step, so every registered arch still serves.

Greedy token streams are unchanged from the wave engine: prefill yields each
request's first token, every decode step feeds the newest token back.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.configs.base import ArchConfig
from repro.serve.engine import ServeEngine
from repro.serve.params import PrecisionParams, SamplingParams


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0
    tokens_out: int = 0


class Server:
    """Blocking facade: submits every request to a ``ServeEngine`` and runs
    it to completion.  ``batch_size`` bounds concurrent slots (continuous
    batching refills them as requests finish — no wave barriers), ``max_len``
    sizes the KV page pool so every slot can reach it."""

    def __init__(
        self,
        arch: ArchConfig,
        params,
        *,
        batch_size: int = 4,
        max_len: int = 512,
        quantize: bool = True,
        mesh=None,
        page_size: int = 16,
    ):
        self.arch = arch
        self.mesh = mesh
        self.batch_size = batch_size
        self.max_len = max_len
        self.w_bits = arch.serve_w_bits if quantize else 16
        self.engine = None
        if ServeEngine.supports(arch):
            pages_per_slot = -(-max_len // page_size)
            self.engine = ServeEngine(
                arch,
                params,
                max_slots=batch_size,
                num_pages=batch_size * pages_per_slot,
                page_size=page_size,
                mesh=mesh,
            )
        else:  # recurrent-cache archs: static-wave fallback
            from repro.models import transformer as model_lib

            self._params = (
                model_lib.quantize_params(params, arch.serve_w_bits)
                if quantize
                else params
            )
            import jax

            self._prefill = jax.jit(
                lambda p, b: model_lib.prefill(p, b, arch, max_len, mesh)
            )
            self._decode = jax.jit(
                lambda p, t, c: model_lib.decode_step(p, t, c, arch, mesh)
            )
        self.stats = ServeStats()

    @property
    def params(self):
        """Weights actually served (quantized view when enabled)."""
        if self.engine is not None:
            return self.engine.params_for(self.w_bits)
        return self._params

    def serve(self, requests: list[Request], greedy: bool = True) -> list[Request]:
        if not greedy:
            raise NotImplementedError("engine decoding is greedy-only")
        if self.engine is None:
            return self._serve_waves(requests)
        precision = PrecisionParams(w_bits=self.w_bits)
        handles = [
            self.engine.submit(
                r.prompt,
                SamplingParams(max_new_tokens=r.max_new_tokens),
                precision,
                rid=r.rid,
            )
            for r in requests
        ]
        self.engine.run()
        for req, h in zip(requests, handles):
            req.out_tokens = list(h.out_tokens)
            req.done = h.done
        es = self.engine.stats
        self.stats = ServeStats(
            prefill_s=es.prefill_s,
            decode_s=es.decode_s,
            decode_steps=es.decode_steps,
            tokens_out=es.tokens_out,
        )
        return requests

    # ------------------------------------------------- static-wave fallback
    def _make_batch(self, reqs: list[Request]) -> dict:
        import jax.numpy as jnp

        s = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), s), np.int32)
        for i, r in enumerate(reqs):
            toks[i, s - len(r.prompt):] = r.prompt  # left-pad to align last token
        batch = {"tokens": jnp.asarray(toks)}
        if self.arch.prefix_len:
            from repro.models.frontends import prefix_embeddings

            batch["prefix_emb"] = prefix_embeddings(self.arch, len(reqs))
        return batch

    def _serve_waves(self, requests: list[Request]) -> list[Request]:
        """The pre-engine scheduler: waves of batch_size, shared positions."""
        import jax
        import jax.numpy as jnp

        pending = list(requests)
        while pending:
            wave = pending[: self.batch_size]
            pending = pending[self.batch_size:]
            t0 = time.perf_counter()
            batch = self._make_batch(wave)
            logits, cache = self._prefill(self._params, batch)
            jax.block_until_ready(logits)
            self.stats.prefill_s += time.perf_counter() - t0
            max_new = max(r.max_new_tokens for r in wave)
            t0 = time.perf_counter()
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            for _ in range(max_new):
                for i, r in enumerate(wave):
                    if len(r.out_tokens) < r.max_new_tokens:
                        r.out_tokens.append(int(tok[i, 0]))
                logits, cache = self._decode(self._params, tok, cache)
                tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
                self.stats.decode_steps += 1
            jax.block_until_ready(logits)
            self.stats.decode_s += time.perf_counter() - t0
            for r in wave:
                r.done = True
                self.stats.tokens_out += len(r.out_tokens)
        return requests
