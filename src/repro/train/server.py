"""Serving engine: batched request scheduling over the quantized model.

The paper's purpose — efficient multi-precision inference — lands here: the
engine holds int4/int8-quantized weights (quantize_params) and an int8 KV
cache, admits requests into a fixed-size batch, prefills admitted prompts,
then decodes steps for the whole batch until every request hits its token
budget (continuous-batching-lite: finished slots are refilled from the queue
between decode bursts).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as model_lib


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0
    tokens_out: int = 0


class Server:
    def __init__(
        self,
        arch: ArchConfig,
        params,
        *,
        batch_size: int = 4,
        max_len: int = 512,
        quantize: bool = True,
        mesh=None,
    ):
        self.arch = arch
        self.mesh = mesh
        self.batch_size = batch_size
        self.max_len = max_len
        self.params = (
            model_lib.quantize_params(params, arch.serve_w_bits) if quantize else params
        )
        self._prefill = jax.jit(
            lambda p, b: model_lib.prefill(p, b, arch, max_len, mesh),
        )
        self._decode = jax.jit(
            lambda p, t, c: model_lib.decode_step(p, t, c, arch, mesh),
        )
        self.stats = ServeStats()

    def _make_batch(self, reqs: list[Request]) -> dict:
        s = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), s), np.int32)
        for i, r in enumerate(reqs):
            toks[i, s - len(r.prompt):] = r.prompt  # left-pad to align last token
        batch = {"tokens": jnp.asarray(toks)}
        if self.arch.prefix_len:
            from repro.models.frontends import prefix_embeddings

            batch["prefix_emb"] = prefix_embeddings(self.arch, len(reqs))
        return batch

    def serve(self, requests: list[Request], greedy: bool = True) -> list[Request]:
        """Static-batch scheduler: processes requests in waves of batch_size."""
        pending = list(requests)
        while pending:
            wave = pending[: self.batch_size]
            pending = pending[self.batch_size:]
            t0 = time.perf_counter()
            batch = self._make_batch(wave)
            logits, cache = self._prefill(self.params, batch)
            jax.block_until_ready(logits)
            self.stats.prefill_s += time.perf_counter() - t0
            max_new = max(r.max_new_tokens for r in wave)
            t0 = time.perf_counter()
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            for step in range(max_new):
                for i, r in enumerate(wave):
                    if len(r.out_tokens) < r.max_new_tokens:
                        r.out_tokens.append(int(tok[i, 0]))
                logits, cache = self._decode(self.params, tok, cache)
                tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
                self.stats.decode_steps += 1
            jax.block_until_ready(logits)
            self.stats.decode_s += time.perf_counter() - t0
            for r in wave:
                r.done = True
                self.stats.tokens_out += len(r.out_tokens)
        return requests
