from repro.train.trainer import Trainer, TrainConfig, make_train_step

__all__ = ["Trainer", "TrainConfig", "make_train_step"]
