"""Training loop: pjit step with gradient accumulation, clipping, LR schedule,
async checkpointing, straggler monitoring, and crash-resume.

Compute/comm overlap: with gradient accumulation the per-microbatch gradient
psum is exposed inside the scan, so XLA's latency-hiding scheduler can overlap
collective traffic with the next microbatch's compute (flags set by
launch/train.py).  Optional int8 gradient compression (error feedback) for
data-parallel meshes routes the reduction through
distributed/collectives.compressed_grad_reduce.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.distributed.fault import StepMonitor
from repro.models import transformer as model_lib
from repro.optim import make_optimizer
from repro.optim.schedules import cosine_schedule


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    microbatches: int = 1  # gradient accumulation factor
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def _clip_by_global_norm(tree, max_norm: float):
    norm = _global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def make_train_step(
    arch: ArchConfig,
    tc: TrainConfig,
    mesh=None,
) -> Callable:
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    Gradient accumulation: the global batch splits into `tc.microbatches`
    equal microbatches scanned sequentially; grads average across them.
    """
    opt_init, opt_update = make_optimizer(arch.optimizer)
    lr_fn = cosine_schedule(tc.lr, tc.warmup, tc.total_steps)

    def loss_fn(params, batch):
        loss, metrics = model_lib.train_loss(params, batch, arch, mesh)
        return loss, metrics

    def step_fn(params, opt_state, batch, step):
        if tc.microbatches > 1:
            def micro(carry, mb):
                acc = carry
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / tc.microbatches, acc, grads
                )
                return acc, (loss, metrics["ce"])

            mbs = jax.tree.map(
                lambda x: x.reshape(tc.microbatches, -1, *x.shape[1:]), batch
            )
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, ces) = jax.lax.scan(micro, zero, mbs)
            loss, ce = jnp.mean(losses), jnp.mean(ces)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            ce = metrics["ce"]
        grads, gnorm = _clip_by_global_norm(grads, tc.grad_clip)
        updates, opt_state = opt_update(grads, opt_state, params, lr_fn(step))
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
        return params, opt_state, {"loss": loss, "ce": ce, "grad_norm": gnorm}

    return step_fn, opt_init


@dataclass
class Trainer:
    arch: ArchConfig
    tc: TrainConfig
    data: DataConfig
    mesh: Any = None
    seed: int = 0
    monitor: StepMonitor = field(default_factory=StepMonitor)

    def __post_init__(self):
        self.ckpt = CheckpointManager(self.tc.ckpt_dir, keep=self.tc.ckpt_keep)
        self.step_fn, self.opt_init = make_train_step(self.arch, self.tc, self.mesh)
        self._jit_step = jax.jit(self.step_fn, donate_argnums=(0, 1))
        self.history: list[dict] = []

    def init_state(self):
        params = model_lib.init_params(self.arch, jax.random.PRNGKey(self.seed))
        return params, self.opt_init(params)

    def run(
        self,
        num_steps: int,
        start_step: int = 0,
        fail_at: Optional[int] = None,  # fault-injection hook (tests)
    ) -> dict:
        if start_step == -1 or (start_step == 0 and self.ckpt.latest_step() is not None):
            latest = self.ckpt.latest_step()
            if latest is not None:
                params, opt_state = self._restore(latest)
                start_step = latest
            else:
                params, opt_state = self.init_state()
                start_step = 0
        else:
            params, opt_state = self.init_state()

        for step in range(start_step, num_steps):
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            batch = make_batch(self.data, step, self.arch)
            t0 = time.perf_counter()
            params, opt_state, metrics = self._jit_step(
                params, opt_state, batch, jnp.asarray(step, jnp.int32)
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            straggler = self.monitor.observe(step, dt)
            rec = {
                "step": step,
                "loss": float(metrics["loss"]),
                "grad_norm": float(metrics["grad_norm"]),
                "sec": dt,
                "straggler": straggler,
            }
            self.history.append(rec)
            if (step + 1) % self.tc.ckpt_every == 0 or step + 1 == num_steps:
                self.ckpt.save(step + 1, (params, opt_state), blocking=False)
        self.ckpt.wait()
        return {"params": params, "opt_state": opt_state, "history": self.history}

    def _restore(self, step: int):
        proto = self.init_state()
        state, _ = self.ckpt.restore(step, target=proto)
        # dtype restoration: np.load gives exact dtypes; re-put as jnp
        return jax.tree.map(jnp.asarray, state)
