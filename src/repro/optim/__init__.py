from repro.optim.optimizers import OptState, adafactor, adamw, adamw8bit, make_optimizer
from repro.optim.schedules import cosine_schedule

__all__ = ["OptState", "adamw", "adafactor", "adamw8bit", "make_optimizer", "cosine_schedule"]
