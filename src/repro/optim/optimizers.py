"""Optimizers, implemented from scratch (no optax dependency).

Three state regimes, because optimizer memory is THE constraint for the
1e12-parameter arch on a 256-chip pod (16 GB HBM each):

  * ``adamw``      — fp32 m/v (8 bytes/param of state): fine to ~10B params.
  * ``adamw8bit``  — blockwise-int8 m/v with per-block fp32 scales (~2.06
    bytes/param): the paper's quantization idea applied to optimizer state.
  * ``adafactor``  — factored second moment, no first moment (O(rows+cols)
    state): what kimi-k2-1t uses for the training dry-run (Adam states for
    1e12 params cannot fit 256 x 16 GB).

All are pytree->pytree pure functions: (grads, state, params) -> (updates,
state), pre-scaled by the LR schedule in the trainer; weight decay is
decoupled (AdamW-style).  States shard like their parameters (ZeRO-style
via the same name-pattern rules).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Any


# ----------------------------------------------------------------- AdamW ----
def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, wd: float = 0.01):
    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), {"m": zeros, "v": jax.tree.map(jnp.copy, zeros)})

    def update(grads, state: OptState, params, lr):
        t = state.step + 1
        tf = t.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state.inner["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.inner["v"], grads)
        def upd(m_, v_, p):
            mh = m_ / (1 - b1 ** tf)
            vh = v_ / (1 - b2 ** tf)
            return (-lr * (mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32))).astype(p.dtype)
        updates = jax.tree.map(upd, m, v, params)
        return updates, OptState(t, {"m": m, "v": v})

    return init, update


# ------------------------------------------------------------ 8-bit AdamW ----
_BLOCK = 256


def _q8(x: jnp.ndarray):
    """Blockwise 8-bit quantization with a quadratic codebook (flat fp32 in,
    int8 code + per-block fp32 scale out).

    value = scale * sign(q) * (|q|/127)^2 — the nonlinear code concentrates
    resolution near zero, where Adam's m/v live (Dettmers' 8-bit optimizers
    use a dynamic codebook for the same reason; plain linear int8 gives small
    elements ~100% relative error and wrecks the m/sqrt(v) ratio)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blk = flat.reshape(-1, _BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blk), axis=1, keepdims=True), 1e-30)
    unit = blk / scale  # [-1, 1]
    q = jnp.clip(jnp.round(jnp.sign(unit) * jnp.sqrt(jnp.abs(unit)) * 127.0), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def _dq8(q: jnp.ndarray, scale: jnp.ndarray, shape, size) -> jnp.ndarray:
    u = q.astype(jnp.float32) / 127.0
    val = jnp.sign(u) * jnp.square(u) * scale
    return val.reshape(-1)[:size].reshape(shape)


def adamw8bit(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, wd: float = 0.01):
    """AdamW with int8 m/v (blockwise scales) — SPEED's multi-precision idea
    applied to optimizer state (~4x memory cut vs fp32 Adam)."""

    def init(params):
        def z(p):
            q, s = _q8(jnp.zeros(p.size, jnp.float32))
            return {"q": q, "s": s}
        return OptState(
            jnp.zeros((), jnp.int32),
            {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)},
        )

    def update(grads, state: OptState, params, lr):
        t = state.step + 1
        tf = t.astype(jnp.float32)

        def upd(mq, vq, g, p):
            gf = g.astype(jnp.float32)
            m = _dq8(mq["q"], mq["s"], p.shape, p.size) * b1 + (1 - b1) * gf
            v = _dq8(vq["q"], vq["s"], p.shape, p.size) * b2 + (1 - b2) * jnp.square(gf)
            mh = m / (1 - b1 ** tf)
            vh = v / (1 - b2 ** tf)
            u = (-lr * (mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32))).astype(p.dtype)
            mq2, ms2 = _q8(m)
            vq2, vs2 = _q8(v)
            return u, {"q": mq2, "s": ms2}, {"q": vq2, "s": vs2}

        flat_u, flat_m, flat_v = [], [], []
        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_m = treedef.flatten_up_to(state.inner["m"])
        leaves_v = treedef.flatten_up_to(state.inner["v"])
        leaves_p = treedef.flatten_up_to(params)
        for mq, vq, g, p in zip(leaves_m, leaves_v, leaves_g, leaves_p):
            u, m2, v2 = upd(mq, vq, g, p)
            flat_u.append(u)
            flat_m.append(m2)
            flat_v.append(v2)
        updates = jax.tree_util.tree_unflatten(treedef, flat_u)
        return updates, OptState(
            t,
            {
                "m": jax.tree_util.tree_unflatten(treedef, flat_m),
                "v": jax.tree_util.tree_unflatten(treedef, flat_v),
            },
        )

    return init, update


# -------------------------------------------------------------- Adafactor ----
def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0, decay: float = 0.8, wd: float = 0.0):
    """Factored second-moment optimizer (Shazeer & Stern 2018): state is
    O(rows + cols) per matrix — the only regime that fits 1e12 params on a
    single pod."""

    def init(params):
        def z(p):
            if p.ndim >= 2:
                return {
                    "r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return OptState(jnp.zeros((), jnp.int32), jax.tree.map(z, params, is_leaf=lambda x: not isinstance(x, dict)))

    def update(grads, state: OptState, params, lr):
        t = state.step + 1
        beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(st, g, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if p.ndim >= 2:
                r = beta * st["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
                c = beta * st["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rmean = jnp.mean(r, axis=-1, keepdims=True)
                vhat = (r / jnp.maximum(rmean, eps))[..., None] * c[..., None, :]
                u = gf / jnp.sqrt(jnp.maximum(vhat, eps))
                st2 = {"r": r, "c": c}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = gf / jnp.sqrt(jnp.maximum(v, eps))
                st2 = {"v": v}
            # update clipping (RMS(u) <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            out = -lr * (u + wd * p.astype(jnp.float32))
            return out.astype(p.dtype), st2

        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_s = treedef.flatten_up_to(state.inner)
        leaves_p = treedef.flatten_up_to(params)
        outs = [upd(s, g, p) for s, g, p in zip(leaves_s, leaves_g, leaves_p)]
        updates = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        inner = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return updates, OptState(t, inner)

    return init, update


def make_optimizer(name: str, lr_unused: float = 0.0):
    if name == "adamw":
        return adamw()
    if name == "adamw8bit":
        return adamw8bit()
    if name == "adafactor":
        return adafactor()
    raise ValueError(f"unknown optimizer {name!r}")
