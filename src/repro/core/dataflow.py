"""FF / CF / mixed dataflow mapping (paper Sec. II-C).

SPEED schedules a convolution layer onto the SAU with one of two strategies:

  * **FF (feature-map-first)** — pre-fetch a spatial tile of ONE input-channel
    element-group, broadcast it, and sweep the kernel across it.  The halo
    between successive stages (Fig. 2a, blue/red overlap) is reused, so each
    external input element is fetched ~once.  The price: partial sums for the
    whole spatial tile live in the VRF and are written/re-read once per
    input-channel pass ("extra time is wasted in transferring the partial
    results between stages").

  * **CF (channel-first)** — pre-fetch along the input-channel dimension and
    accumulate the channel reduction *inside* the SAU accumulators; no
    partial-sum traffic and a small VRF footprint, but spatial halo is not
    kept, so inputs in the K×K overlap are re-fetched (factor ~(TILE_H+K-1)/
    TILE_H) — harmless for 1×1 kernels, wasteful for large K.

  * **mixed** — per layer, pick whichever the cost model says is faster
    (paper Fig. 3: CF wins conv1x1, FF wins K>=3).

This module produces geometry/traffic statistics (`ScheduleStats`) consumed by
`core/perfmodel.py` (cycles/energy) and mirrored by the Pallas conv path's
grid orders (`kernels/ops.py::mpconv`, which lowers onto the `kernels/mpmm.py`
matmul core).  The same selector drives matmul schedule choice for the
quantized LM serving path (`models/layers.py::dense` dispatching quantized
weights from `models/layers.py::quantize_dense_weight` through
`kernels/ops.py::mpmm`): a matmul is a 1x1 convolution, so "CF" maps to
accumulate-in-register (K-inner) tiling and "FF" to
output-stationary-with-spill (K-outer) tiling.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass

from repro.core.isa import Dataflow
from repro.core.precision import Precision

__all__ = ["ConvLayer", "HardwareGeometry", "ScheduleStats", "ff_schedule", "cf_schedule", "schedule"]


@dataclass(frozen=True)
class ConvLayer:
    """One convolutional layer (square spatial, as in the paper's benchmarks)."""

    name: str
    cin: int
    cout: int
    k: int
    h: int  # input height
    w: int  # input width
    stride: int = 1
    padding: int = 0

    @property
    def h_out(self) -> int:
        return (self.h + 2 * self.padding - self.k) // self.stride + 1

    @property
    def w_out(self) -> int:
        return (self.w + 2 * self.padding - self.k) // self.stride + 1

    @property
    def macs(self) -> int:
        return self.h_out * self.w_out * self.cout * self.cin * self.k * self.k

    @property
    def ops(self) -> int:
        return 2 * self.macs


@dataclass(frozen=True)
class HardwareGeometry:
    """SAU/lane geometry (paper Sec. III-A experimental setup)."""

    lanes: int = 4
    tile_r: int = 4  # feature-map height parallelism per lane (TILE_H)
    tile_c: int = 4  # output-channel parallelism per lane
    vlen_bits: int = 4096  # VRF register width (same as Ara for fairness)
    n_vregs: int = 32
    op_queue_elems: int = 512  # operand-queue capacity (unified elements)

    @property
    def oc_parallel(self) -> int:
        return self.lanes * self.tile_c

    @property
    def pe_elems_per_cycle(self) -> int:
        """Unified elements the whole processor reduces per cycle."""
        return self.lanes * self.tile_r * self.tile_c

    @property
    def vrf_capacity_bits(self) -> int:
        return self.lanes * self.n_vregs * self.vlen_bits


@dataclass(frozen=True)
class ScheduleStats:
    """Traffic/geometry of one (layer, precision, dataflow) mapping.

    Units: ``elements`` are unified elements (16-bit containers carrying 1/4/16
    operands at 16/8/4-bit); ``values`` are 32-bit partial sums.
    """

    layer: ConvLayer
    precision: Precision
    dataflow: Dataflow
    sau_bursts: int  # element-reductions issued to the SAU (cycles of compute)
    burst_chains: int  # independent accumulate chains (fill/drain events)
    ext_input_elems: int  # unified input elements fetched from external memory
    ext_weight_elems: int  # unified weight elements fetched
    ext_output_values: int  # final outputs written back
    partial_values: int  # partial sums moved VRF<->SAU between stages (FF cost)
    drain_events: int  # accumulator-bank drains (one per output column chain)
    vrf_edge_elems: int  # input elements read VRF->SA edge (port traffic)
    wt_edge_elems: int  # weight elements read VRF->SA edge (queue-cached)
    vrf_peak_bits: int  # peak VRF residency
    vsald_count: int  # number of load instructions issued
    vsam_count: int  # number of arithmetic instructions issued

    @property
    def utilization_denominator(self) -> int:
        return self.sau_bursts


def _ceil(a: int, b: int) -> int:
    return math.ceil(a / b)


@functools.lru_cache(maxsize=None)
def ff_schedule(layer: ConvLayer, precision: Precision, hw: HardwareGeometry = HardwareGeometry()) -> ScheduleStats:
    g = precision.spec.ops_per_element
    ce = _ceil(layer.cin, g)  # input-channel unified elements
    oc_tiles = _ceil(layer.cout, hw.oc_parallel)
    h_tiles = _ceil(layer.h_out, hw.tile_r)
    # Compute: every (output tile row-group, column, oc tile, kernel pos, channel elem)
    sau_bursts = h_tiles * layer.w_out * oc_tiles * layer.k * layer.k * ce
    # Columns stream through the systolic array back-to-back; the pipeline only
    # flushes when the resident weight set changes: per (oc tile, row tile,
    # channel-element stage) under FF.
    burst_chains = h_tiles * oc_tiles * ce
    # Inputs: the spatial sweep keeps the sliding halo resident (one channel
    # strip at a time — tiny), so each input element is fetched once per
    # oc-tile sweep; if ALL channel strips fit simultaneously the image even
    # persists across oc tiles.
    in_elems = _ceil(layer.cin, g) * layer.h * layer.w
    in_space_ops = 8 * hw.vlen_bits // 16  # v0..v7 slab
    all_strip_ops = ce * (hw.tile_r + layer.k - 1) * (layer.w + 2 * layer.padding) * g
    in_refetch = 1 if all_strip_ops <= in_space_ops else oc_tiles
    ext_input_elems = in_elems * in_refetch
    # Weights: fetched once (reused across stages — paper: "Weights are reused
    # in the second stage to minimize off-chip data movement").
    ext_weight_elems = _ceil(layer.cin, g) * layer.cout * layer.k * layer.k
    # Partial sums: spatial-first order => outputs of the whole spatial strip
    # are written to VRF and re-read for each subsequent channel-element pass.
    outputs = layer.h_out * layer.w_out * layer.cout
    partial_values = outputs * max(ce - 1, 0) * 2  # store + reload
    # VRF peak: input spatial tile + partial outputs for the strip.
    strip_outputs_bits = layer.h_out * layer.w_out * min(layer.cout, hw.oc_parallel) * 32
    input_tile_bits = (hw.tile_r + layer.k - 1) * layer.w * 16
    vrf_peak_bits = strip_outputs_bits + input_tile_bits
    drain_events = h_tiles * layer.w_out * oc_tiles  # final-stage drain per column
    # VRF->SA input-edge traffic: FF streams the channel strip once per stage;
    # horizontal window reuse happens inside the systolic array registers.
    w_pad_ff = layer.w + 2 * layer.padding
    vrf_edge_elems = h_tiles * (hw.tile_r + layer.k - 1) * w_pad_ff * ce * oc_tiles
    # weight edge: queue-cached per strip, re-streamed once per stage
    wt_edge_elems = h_tiles * oc_tiles * ce * layer.k * layer.k * hw.oc_parallel
    vsald = oc_tiles * (h_tiles * ce + _ceil(ext_weight_elems, hw.oc_parallel))
    return ScheduleStats(
        layer=layer,
        precision=precision,
        dataflow=Dataflow.FF,
        sau_bursts=sau_bursts,
        burst_chains=burst_chains,
        ext_input_elems=ext_input_elems,
        ext_weight_elems=ext_weight_elems,
        ext_output_values=outputs,
        partial_values=partial_values,
        drain_events=drain_events,
        vrf_edge_elems=vrf_edge_elems,
        wt_edge_elems=wt_edge_elems,
        vrf_peak_bits=vrf_peak_bits,
        vsald_count=vsald,
        vsam_count=sau_bursts,
    )


@functools.lru_cache(maxsize=None)
def cf_schedule(layer: ConvLayer, precision: Precision, hw: HardwareGeometry = HardwareGeometry()) -> ScheduleStats:
    g = precision.spec.ops_per_element
    ce = _ceil(layer.cin, g)
    oc_tiles = _ceil(layer.cout, hw.oc_parallel)
    h_tiles = _ceil(layer.h_out, hw.tile_r)
    sau_bursts = h_tiles * layer.w_out * oc_tiles * layer.k * layer.k * ce
    # CF accumulates the whole reduction (k*k*ce) inside the SAU and the weight
    # set stays resident across the column sweep: one flush per (oc tile, row
    # tile), and no partial-sum traffic at all.
    burst_chains = h_tiles * oc_tiles
    # Inputs: channel-first prefetch trades spatial residency for channel
    # residency.  Three capacity tiers:
    #   (a) the full-width multi-channel row strip fits the input register
    #       space -> horizontal halo reused, only the vertical overlap between
    #       row tiles re-fetches ((tile_r+k-1)/tile_r), and the strip persists
    #       across oc tiles;
    #   (b) only a one-column multi-channel window fits -> CF walks column by
    #       column and the k x k overlap re-fetches both ways
    #       (k * (tile_r+k-1)/tile_r) — THE reason CF loses on large kernels
    #       (paper: "suitable for smaller convolution kernels with low reuse
    #       requirements");
    #   (c) re-streamed per oc tile in either case when not resident.
    w_pad = layer.w + 2 * layer.padding
    in_elems = _ceil(layer.cin, g) * layer.h * layer.w
    in_space_ops = 8 * hw.vlen_bits // 16
    row_window_ops = ce * (hw.tile_r + layer.k - 1) * w_pad * g
    col_window_ops = ce * (hw.tile_r + layer.k - 1) * layer.k * g
    if row_window_ops <= in_space_ops:
        halo_refetch = (hw.tile_r + layer.k - 1) / hw.tile_r
        in_refetch = 1
    elif col_window_ops <= in_space_ops:
        halo_refetch = layer.k * (hw.tile_r + layer.k - 1) / hw.tile_r
        in_refetch = oc_tiles
    else:  # not even one column window resident: full k x k re-fetch
        halo_refetch = float(layer.k * layer.k)
        in_refetch = oc_tiles
    ext_input_elems = math.ceil(in_elems * halo_refetch) * in_refetch
    # Weights: stay VRF-resident across row tiles when the per-oc-tile slice
    # fits the weight register space; otherwise they stream once per row tile.
    w_elems = _ceil(layer.cin, g) * layer.cout * layer.k * layer.k
    w_ops_per_octile = ce * layer.k * layer.k * hw.tile_c * g  # per lane
    w_space_ops = 8 * hw.vlen_bits // 16  # v8..v15 slab
    w_refetch = 1 if w_ops_per_octile <= w_space_ops else h_tiles
    ext_weight_elems = w_elems * w_refetch
    outputs = layer.h_out * layer.w_out * layer.cout
    # VRF peak: ce channel elements for the active positions + weights slice.
    input_bits = ce * (hw.tile_r + layer.k - 1) * (layer.k + 1) * 16
    weight_bits = ce * layer.k * layer.k * hw.oc_parallel * 16
    vrf_peak_bits = input_bits + weight_bits
    drain_events = h_tiles * layer.w_out * oc_tiles
    # VRF->SA input-edge traffic: the per-column multi-channel window is
    # re-read from the VRF for every output column UNLESS it fits the operand
    # queues (paper Fig. 1: "OP Queues", 25% of lane area) — the structural
    # reason CF loses on large kernels even with ample external bandwidth.
    col_window_elems = ce * (hw.tile_r + layer.k - 1) * layer.k
    if col_window_elems <= hw.op_queue_elems:
        vrf_edge_elems = h_tiles * (hw.tile_r + layer.k - 1) * w_pad * ce * oc_tiles
    else:
        vrf_edge_elems = (
            h_tiles * layer.w_out * layer.k * (hw.tile_r + layer.k - 1) * ce * oc_tiles
        )
    wt_edge_elems = h_tiles * oc_tiles * ce * layer.k * layer.k * hw.oc_parallel
    vsald = oc_tiles * h_tiles * (ce + _ceil(w_elems, hw.oc_parallel))
    return ScheduleStats(
        layer=layer,
        precision=precision,
        dataflow=Dataflow.CF,
        sau_bursts=sau_bursts,
        burst_chains=burst_chains,
        ext_input_elems=ext_input_elems,
        ext_weight_elems=ext_weight_elems,
        ext_output_values=outputs,
        partial_values=0,
        drain_events=drain_events,
        vrf_edge_elems=vrf_edge_elems,
        wt_edge_elems=wt_edge_elems,
        vrf_peak_bits=vrf_peak_bits,
        vsald_count=vsald,
        vsam_count=sau_bursts,
    )


def schedule(
    layer: ConvLayer,
    precision: Precision,
    dataflow: Dataflow,
    hw: HardwareGeometry = HardwareGeometry(),
) -> ScheduleStats:
    return (ff_schedule if dataflow is Dataflow.FF else cf_schedule)(layer, precision, hw)
