"""Analytical cycle / area / energy model of SPEED and the Ara baseline.

The paper evaluates SPEED with cycle-accurate QuestaSim simulation of the RTL
plus Synopsys DC synthesis at TSMC 28nm (Sec. III-A).  We have no RTL here;
instead this module is a calibrated analytical model that

  * converts `core.dataflow.ScheduleStats` into cycle counts using a small set
    of microarchitectural parameters (external-memory bandwidth, VRF port
    bandwidth, systolic fill/drain, issue overhead, load/compute overlap),
  * applies the synthesized constants the paper reports (area, power,
    frequency — Table I) to produce GOPS, GOPS/mm^2 and GOPS/W,
  * implements the same for Ara (the paper's baseline): no 4-bit mode, no
    broadcast loads, no in-SAU accumulation (vmacc over an output-stationary
    vector register), k^2 input re-fetch for convolution windows.

Calibration: the free microarchitectural parameters are fitted once against
the paper's own reported numbers (Table I peaks + Fig. 3/4 ratios) by
``benchmarks/calibrate.py``; the fitted values are frozen below and the
benchmark harness reports both our model's numbers and the paper's alongside
the relative error.  The *qualitative* claims (CF wins 1x1, FF wins K>=3,
mixed > either, SPEED >> Ara, 4-bit ~3x 8-bit) are model outputs, not inputs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.dataflow import (
    ConvLayer,
    HardwareGeometry,
    ScheduleStats,
    cf_schedule,
    ff_schedule,
)
from repro.core.isa import Dataflow
from repro.core.precision import Precision

__all__ = [
    "SpeedModel",
    "AraModel",
    "LayerPerf",
    "select_dataflow",
    "evaluate_layer",
    "evaluate_network",
]


@dataclass(frozen=True)
class LayerPerf:
    layer: ConvLayer
    precision: Precision
    dataflow: Dataflow | None  # None for Ara (single fixed dataflow)
    cycles: float
    gops: float
    area_eff: float  # GOPS / mm^2
    energy_eff: float  # GOPS / W
    utilization: float


@dataclass(frozen=True)
class SpeedModel:
    """SPEED @ TSMC 28nm, 500 MHz, 4 lanes, TILE_R=TILE_C=4, VLEN=4096 (Sec. III-A)."""

    hw: HardwareGeometry = HardwareGeometry()
    freq_hz: float = 500e6
    area_mm2: float = 1.10  # Table I (synthesis constant)
    power_w: float = 0.21516  # Table I (synthesis constant)

    # --- fitted microarchitecture parameters (benchmarks/calibrate.py;
    #     frozen 2026-07-15, loss 3.38 — per-metric errors in EXPERIMENTS.md) ---
    ext_bw_bits: float = 21.722  # external-memory bits / cycle (AXI-like bus)
    vrf_bw_values: float = 11.967  # 32-bit partial sums / cycle VRF<->SAU
    out_bw_values: float = 47.486  # final outputs / cycle writeback
    chain_bubble: float = 0.0  # pipeline bubble when an accumulate chain retires
    issue_cycles: float = 0.0  # sequencer/issue cost per vector instruction
    overlap: float = 0.858  # fraction of load/transfer hidden under compute
    sau_eff: float = 0.575  # operand-requester arbitration / VRF bank-conflict
    #                        efficiency: average fraction of cycles the SA core
    #                        accepts a new unified element (request arbiter,
    #                        Sec. II-B, serializes colliding VRF reads)
    vrf_read_bits: float = 1990.881  # VRF read-port bits / lane / cycle feeding
    #                               the SAU edges: narrow precisions move wider
    #                               unified elements (64-bit at 4-bit mode), so
    #                               the port width caps narrow-mode throughput
    layer_startup: float = 29090.192  # per-layer fixed cost: scalar-core setup,
    #                                first-fetch latency, pipeline warm-up/drain
    col_drain: float = 15.065  # accumulator drain bubble per output-column chain
    #                         (single accumulator bank per PE: the systolic
    #                         drain serializes against the next column's fill;
    #                         negligible for long chains, dominant for the
    #                         short chains of 4-bit / small-ce layers)

    def peak_gops(self, precision: Precision) -> float:
        return (
            self.hw.pe_elems_per_cycle
            * precision.spec.ops_per_mac_cycle
            * self.freq_hz
            / 1e9
        )

    def cycles(self, stats: ScheduleStats) -> float:
        # a unified element is g operands of `bits` width: 16/32/64 bits at
        # 16/8/4-bit precision — narrower ops move MORE operands per element
        # but each element costs more port/bus beats.
        spec = stats.precision.spec
        elem_bits = spec.ops_per_element * spec.bits
        # VRF read-port limit: the SA edges consume operand traffic
        # (vrf_edge_elems + wt_edge_elems) through per-lane read ports of
        # vrf_read_bits/cycle; wide (narrow-precision) elements can make the
        # ports, not the MXU-equivalent array, the binding constraint.
        hw = self.hw
        port_bits = (stats.vrf_edge_elems + stats.wt_edge_elems) * elem_bits
        port_cycles = port_bits / (hw.lanes * self.vrf_read_bits)
        compute = (
            max(stats.sau_bursts / self.sau_eff, port_cycles)
            + self.chain_bubble * stats.burst_chains
            + self.col_drain * stats.drain_events
        )
        load_bits = (stats.ext_input_elems + stats.ext_weight_elems) * elem_bits
        loads = load_bits / self.ext_bw_bits
        transfers = stats.partial_values / self.vrf_bw_values
        writeback = stats.ext_output_values / self.out_bw_values
        issue = self.issue_cycles * (stats.vsald_count + stats.vsam_count / 64.0)
        noncompute = loads + transfers + writeback
        # A fraction `overlap` of non-compute work hides under the SAU bursts.
        hidden = min(noncompute * self.overlap, compute * 0.95)
        return compute + noncompute - hidden + issue + self.layer_startup

    def evaluate(self, layer: ConvLayer, precision: Precision, dataflow: Dataflow) -> LayerPerf:
        stats = (ff_schedule if dataflow is Dataflow.FF else cf_schedule)(layer, precision, self.hw)
        cyc = self.cycles(stats)
        t = cyc / self.freq_hz
        gops = layer.ops / t / 1e9
        return LayerPerf(
            layer=layer,
            precision=precision,
            dataflow=dataflow,
            cycles=cyc,
            gops=gops,
            area_eff=gops / self.area_mm2,
            energy_eff=gops / self.power_w,
            utilization=gops / self.peak_gops(precision),
        )


@dataclass(frozen=True)
class AraModel:
    """Ara baseline (Table I column 1): RVV 1.0, 4 lanes, VLEN=4096, 500 MHz.

    Ara has 64-bit SIMD MAC datapaths per lane: 4x16-bit or 8x8-bit MACs per
    lane per cycle; no 4-bit support, no broadcast loads (each lane receives
    its ordered slice, so convolution windows re-fetch inputs ~k^2 times via
    strided/slide operations), and channel reductions accumulate through
    vector registers (vmacc), costing a read-modify-write per element.
    """

    lanes: int = 4
    freq_hz: float = 500e6
    area_mm2: float = 0.44  # Table I
    power_w: float = 0.06114  # Table I

    # --- fitted parameters (frozen with the SpeedModel fit) ---
    ext_bw_bits: float = 16.0  # external-memory bits / cycle
    slide_penalty: float = 6.0  # strided-window overhead factor on loads
    issue_cycles: float = 63.713
    overlap: float = 0.1  # in-order core hides less of the load latency
    layer_startup: float = 29863.069  # per-layer vsetvl/strip-mining fixed cost
    w16_penalty: float = 1.457  # RVV widening MAC (vwmacc, EMUL=2 destination)
    #                           throughput penalty: 16-bit MACs accumulate into
    #                           32-bit vd, halving effective SIMD rate; 8-bit
    #                           convs accumulate in 16-bit and re-widen rarely.

    def simd_macs(self, precision: Precision) -> float:
        if precision is Precision.INT4:
            raise ValueError("Ara has no 4-bit integer mode (Table I)")
        base = self.lanes * (64 // precision.spec.bits)
        if precision is Precision.INT16:
            return base / self.w16_penalty
        return base

    def peak_gops(self, precision: Precision) -> float:
        return self.simd_macs(precision) * 2 * self.freq_hz / 1e9

    def evaluate(self, layer: ConvLayer, precision: Precision) -> LayerPerf:
        macs = layer.macs
        compute = macs / self.simd_macs(precision)
        # vmacc accumulation: partial sums live in a vector register and are
        # re-read/written every channel step => an extra register pass per MAC
        # group, modelled as 1 extra cycle per SIMD group per k*k*cin step is
        # already inside compute; the dominant extra is data movement:
        in_bits = layer.cin * layer.h * layer.w * precision.spec.bits
        # no broadcast + window slides: inputs re-fetched ~k (vertical reuse
        # via slides exists, horizontal does not) x oc-tile sweeps
        oc_passes = math.ceil(layer.cout / (self.lanes * 4))
        load_bits = in_bits * layer.k * self.slide_penalty * oc_passes
        w_bits = layer.cout * layer.cin * layer.k * layer.k * precision.spec.bits
        out_bits = layer.h_out * layer.w_out * layer.cout * 32
        loads = (load_bits + w_bits + out_bits) / self.ext_bw_bits
        # instruction issue: one vmacc per (k*k*cin) per output strip
        n_instr = layer.k * layer.k * layer.cin * math.ceil(layer.h_out * layer.w_out / 256) * oc_passes
        issue = self.issue_cycles * n_instr / 8.0
        hidden = min(loads * self.overlap, compute * 0.95)
        cyc = compute + loads - hidden + issue + self.layer_startup
        t = cyc / self.freq_hz
        gops = layer.ops / t / 1e9
        return LayerPerf(
            layer=layer,
            precision=precision,
            dataflow=None,
            cycles=cyc,
            gops=gops,
            area_eff=gops / self.area_mm2,
            energy_eff=gops / self.power_w,
            utilization=gops / self.peak_gops(precision),
        )


def select_dataflow(
    layer: ConvLayer, precision: Precision, model: SpeedModel | None = None
) -> Dataflow:
    """The paper's *mixed* strategy: per layer, pick the faster dataflow."""
    model = model or SpeedModel()
    ff = model.evaluate(layer, precision, Dataflow.FF)
    cf = model.evaluate(layer, precision, Dataflow.CF)
    return Dataflow.FF if ff.cycles <= cf.cycles else Dataflow.CF


def evaluate_layer(
    layer: ConvLayer,
    precision: Precision,
    strategy: str = "mixed",
    model: SpeedModel | None = None,
) -> LayerPerf:
    model = model or SpeedModel()
    if strategy == "ff":
        return model.evaluate(layer, precision, Dataflow.FF)
    if strategy == "cf":
        return model.evaluate(layer, precision, Dataflow.CF)
    if strategy == "mixed":
        df = select_dataflow(layer, precision, model)
        return model.evaluate(layer, precision, df)
    raise ValueError(f"unknown strategy {strategy!r}")


def evaluate_network(
    layers: list[ConvLayer],
    precision: Precision,
    strategy: str = "mixed",
    model: SpeedModel | None = None,
) -> dict:
    """Network-level metrics the paper reports: total-ops / total-time GOPS
    (equivalently, cycle-weighted) and the derived efficiencies."""
    model = model or SpeedModel()
    perfs = [evaluate_layer(l, precision, strategy, model) for l in layers]
    total_ops = sum(p.layer.ops for p in perfs)
    total_cycles = sum(p.cycles for p in perfs)
    gops = total_ops / (total_cycles / model.freq_hz) / 1e9
    return {
        "layers": perfs,
        "gops": gops,
        "area_eff": gops / model.area_mm2,
        "energy_eff": gops / model.power_w,
        "peak_layer_gops": max(p.gops for p in perfs),
        "total_cycles": total_cycles,
    }


def evaluate_network_ara(
    layers: list[ConvLayer], precision: Precision, model: AraModel | None = None
) -> dict:
    model = model or AraModel()
    perfs = [model.evaluate(l, precision) for l in layers]
    total_ops = sum(p.layer.ops for p in perfs)
    total_cycles = sum(p.cycles for p in perfs)
    gops = total_ops / (total_cycles / model.freq_hz) / 1e9
    return {
        "layers": perfs,
        "gops": gops,
        "area_eff": gops / model.area_mm2,
        "energy_eff": gops / model.power_w,
        "peak_layer_gops": max(p.gops for p in perfs),
        "total_cycles": total_cycles,
    }
