"""Precision definitions for SPEED's multi-precision datapath.

The paper (Sec. II-C) unifies multi-precision data representation by packing
adjacent operands along the input-channel dimension into a fixed-width
"unified element":

    16-bit mode: 1 operand / element
     8-bit mode: 4 operands / element
     4-bit mode: 16 operands / element

i.e. a unified element is always 16 bits x <lanes-per-element> wide in the
VRF; what changes is how many (narrower) operands ride in it.  A PE holds
sixteen 4-bit multipliers, dynamically combined into

    1 x 16-bit MAC  |  4 x 8-bit MACs  |  16 x 4-bit MACs

per cycle (Sec. II-B).  This module captures that geometry as data the rest
of the stack (SAU model, dataflow cost model, Pallas kernels, quantized LM
layers) shares.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

__all__ = [
    "Precision",
    "PrecisionSpec",
    "PRECISION_SPECS",
    "UNIFIED_ELEMENT_BITS",
    "PE_MULTIPLIERS_4B",
]

# Width of a unified element in the VRF (Sec. II-C: "every adjacent 1, 4 and
# 16 operands are combined into a unified element" under 16/8/4-bit modes).
UNIFIED_ELEMENT_BITS = 16 * 16  # 256 bits: 1x16b at 16 ops.. see spec below
# Each PE integrates sixteen 4-bit multipliers (Sec. II-B).
PE_MULTIPLIERS_4B = 16


class Precision(enum.IntEnum):
    """Operand precisions supported by SPEED's datapath (paper: 4~16 bit)."""

    INT4 = 4
    INT8 = 8
    INT16 = 16

    @property
    def spec(self) -> "PrecisionSpec":
        return PRECISION_SPECS[self]

    @classmethod
    def from_bits(cls, bits: int) -> "Precision":
        try:
            return cls(bits)
        except ValueError:
            raise ValueError(
                f"SPEED supports 4/8/16-bit operands, got {bits}-bit"
            ) from None


@dataclass(frozen=True)
class PrecisionSpec:
    """Static geometry of one precision mode.

    Attributes:
      bits:            operand width in bits.
      ops_per_element: operands packed per unified element (paper Sec. II-C).
      macs_per_pe:     MACs one PE performs per cycle in this mode; equals the
                       number of ways the sixteen 4-bit multipliers combine.
      digits:          number of 4-bit digits per operand (bit-split factor).
      qmin/qmax:       signed integer range.
    """

    bits: int
    ops_per_element: int
    macs_per_pe: int
    digits: int

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1))

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1

    @property
    def ops_per_mac_cycle(self) -> int:
        """Useful INT ops (mul+add = 2) per PE per cycle in this mode."""
        return 2 * self.macs_per_pe


PRECISION_SPECS: dict[Precision, PrecisionSpec] = {
    # digits^2 * macs_per_pe == 16 four-bit multipliers, always fully used:
    Precision.INT16: PrecisionSpec(bits=16, ops_per_element=1, macs_per_pe=1, digits=4),
    Precision.INT8: PrecisionSpec(bits=8, ops_per_element=4, macs_per_pe=4, digits=2),
    Precision.INT4: PrecisionSpec(bits=4, ops_per_element=16, macs_per_pe=16, digits=1),
}


def throughput_scale(precision: Precision) -> int:
    """MAC-throughput multiplier of a PE relative to 16-bit mode."""
    return precision.spec.macs_per_pe


def sanity_check() -> None:
    for p, s in PRECISION_SPECS.items():
        assert s.digits * 4 == s.bits, (p, s)  # operands split into 4-bit digits
        # sixteen 4-bit multipliers fully utilized in every mode:
        assert s.digits * s.digits * s.macs_per_pe == PE_MULTIPLIERS_4B, (p, s)


sanity_check()
