"""Functional model of SPEED's multi-precision Systolic Array Unit (SAU).

Paper Sec. II-B: each lane holds a parameterized ``TILE_R x TILE_C`` array of
PEs; each PE contains sixteen 4-bit multipliers that dynamically combine into
1x16-bit, 4x8-bit, or 16x4-bit MACs per cycle.  Three levels of parallelism:

  * inside a PE  — input-channel dimension (the packed operands of a unified
                   element are reduced inside the PE),
  * across PE columns (TILE_C) — output-channel dimension,
  * across PE rows (TILE_R, with TILE_H spatial positions) — feature-map
    height dimension.

This module is the *bit-accurate numerical model* of that fabric in JAX:

  * :func:`digit_decompose` / :func:`digit_compose` — the radix-16 (4-bit
    digit) split-and-combine identity the hardware uses to build wide
    multiplies out of 4-bit multipliers,
  * :func:`pe_multiply` — one PE's product built ONLY from 4-bit x 4-bit
    partial products (what the sixteen multipliers physically compute),
  * :class:`SAU` — the tile: a multi-precision matmul-accumulate over unified
    elements, jit-able and used by core/interpreter.py as the execute stage
    of VSAM instructions.

Everything here is an *oracle* (plain jnp, no Pallas): kernels/mpmm.py is the
TPU-performance implementation and is tested against the same math.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.core.precision import PE_MULTIPLIERS_4B, Precision

__all__ = ["digit_decompose", "digit_compose", "pe_multiply", "pe_mac", "SAU"]


def digit_decompose(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Splits signed ``bits``-wide integers into radix-16 digits, little-endian.

    Returns an int32 array with a trailing axis of ``bits // 4`` digits.  All
    digits are the *unsigned* low nibbles except the top digit, which keeps the
    sign — exactly the digit convention a two's-complement array multiplier
    sees.  Invariant: ``sum_i digits[..., i] * 16**i == x``.
    """
    ndigits = bits // 4
    x = jnp.asarray(x, jnp.int32)
    digits = []
    rem = x
    for i in range(ndigits - 1):
        d = rem & 0xF  # unsigned low nibble
        digits.append(d)
        rem = (rem - d) >> 4  # exact arithmetic shift after removing nibble
    digits.append(rem)  # signed top digit
    return jnp.stack(digits, axis=-1)


def digit_compose(digits: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`digit_decompose` (last axis = digits)."""
    ndigits = digits.shape[-1]
    weights = 16 ** jnp.arange(ndigits, dtype=jnp.int32)
    return jnp.sum(digits.astype(jnp.int32) * weights, axis=-1)


def pe_multiply(a: jnp.ndarray, b: jnp.ndarray, precision: Precision) -> jnp.ndarray:
    """Product of two signed ``precision``-bit operands computed the way a
    SPEED PE does: as a sum of shifted 4-bit x 4-bit partial products.

    With ``a = sum_i a_i 16^i`` and ``b = sum_j b_j 16^j``:
        ``a*b = sum_{i,j} a_i b_j 16^{i+j}``
    which needs ``digits**2`` of the sixteen 4-bit multipliers — 16 for 16-bit
    (1 MAC/PE), 4 for 8-bit (4 MACs/PE), 1 for 4-bit (16 MACs/PE).
    """
    spec = precision.spec
    da = digit_decompose(a, spec.bits)[..., :, None]  # [..., i, 1]
    db = digit_decompose(b, spec.bits)[..., None, :]  # [..., 1, j]
    partial = da * db  # 4b x 4b products (int32)
    n = spec.digits
    shift = 16 ** (jnp.arange(n, dtype=jnp.int32)[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :])
    assert n * n * spec.macs_per_pe == PE_MULTIPLIERS_4B
    # int32 throughout: every term and the result of a 16x16-bit multiply fit
    # (and wraparound, if forced, matches the 32-bit accumulator semantics)
    return jnp.sum(partial * shift, axis=(-2, -1))


def pe_mac(acc: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, precision: Precision) -> jnp.ndarray:
    """Multiply-accumulate into a 32-bit accumulator (hardware acc register)."""
    prod = pe_multiply(a, b, precision)
    return (acc.astype(jnp.int32) + prod.astype(jnp.int32)).astype(jnp.int32)


@dataclass(frozen=True)
class SAU:
    """One lane's systolic array: TILE_R x TILE_C PEs.

    ``__call__`` performs the matmul-accumulate a burst of VSAM instructions
    maps onto the tile:

        inputs  [R, K]  — R feature-map rows (TILE_H positions), K reduced
                           operands (input-channel dim, PE-internal parallel)
        weights [K, C]  — C output channels across PE columns
        acc     [R, C]  — int32 accumulators

    K is reduced ``ops_per_element`` at a time per cycle (a unified element per
    PE per cycle); the cycle count model lives in core/perfmodel.py.
    """

    tile_r: int = 4
    tile_c: int = 4

    def __call__(
        self,
        acc: jnp.ndarray,
        inputs: jnp.ndarray,
        weights: jnp.ndarray,
        precision: Precision,
        *,
        bit_accurate: bool = False,
    ) -> jnp.ndarray:
        if inputs.ndim != 2 or weights.ndim != 2:
            raise ValueError("SAU operates on [R,K] x [K,C]")
        r, k = inputs.shape
        k2, c = weights.shape
        if k != k2:
            raise ValueError(f"reduction mismatch {k} vs {k2}")
        if r > self.tile_r or c > self.tile_c:
            raise ValueError(
                f"operands [{r},{k}]x[{k},{c}] exceed tile {self.tile_r}x{self.tile_c}"
            )
        if bit_accurate:
            # Build every product from 4-bit partial products (slow oracle).
            prod = pe_multiply(inputs[:, :, None], weights[None, :, :], precision)
            out = jnp.sum(prod, axis=1)
        else:
            out = jnp.einsum(
                "rk,kc->rc",
                inputs.astype(jnp.int32),
                weights.astype(jnp.int32),
                preferred_element_type=jnp.int32,
            )
        return (acc.astype(jnp.int32) + out.astype(jnp.int32)).astype(jnp.int32)

    def cycles(self, r: int, c: int, k_elements: int, precision: Precision) -> int:
        """Cycles to reduce ``k_elements`` unified elements over an [r,c] facet
        (systolic fill/drain + one element per cycle)."""
        del precision  # element throughput is precision-independent by design
        import math

        r_tiles = math.ceil(r / self.tile_r)
        c_tiles = math.ceil(c / self.tile_c)
        fill_drain = self.tile_r + self.tile_c - 2
        return r_tiles * c_tiles * (k_elements + fill_drain)
