"""SPEED's customized RISC-V vector instructions (paper Sec. II-A, Fig. 1).

Three customized instructions extend RVV 1.0:

  * ``VSACFG`` — configuration-setting: precision (4~16-bit) + dataflow
    strategy (FF/CF) + tile geometry, carried in the ``zimm9``/``uimm5``
    immediate spaces (mirroring ``vsetivli``'s encoding style).
  * ``VSALD`` — customized load: loads from external memory base address
    (``rs1``) into the VRFs at destination ``vd``; the ``mop`` bit selects
    *broadcast* distribution (same data to every lane — SPEED's reuse trick)
    vs the standard *ordered* allocation of ``VLE``.
  * ``VSAM``  — customized arithmetic: systolic multiply-accumulate; operands
    at VRF addresses ``vs1``/``vs2``, result accumulated at ``Acc Addr``.

The paper names the fields but (as a 5-page ISCAS paper) does not publish bit
positions; we fix a concrete encoding in the RVV style below and keep it
round-trip tested.  Encodings use the OP-V major opcode (0x57) with funct3 =
0b111 (the vsetvl family slot) for VSACFG and the custom-1 major opcode
(0x2B) for VSALD/VSAM, so they do not collide with standard RVV instructions.

Layouts (bit 31 .. bit 0):

VSACFG  [31]=1 [30]=1 | zimm9[28:20] | uimm5[19:15] | funct3=111 | rd[11:7] | opcode=1010111
  zimm9 = {reserved[8:6], acc_clear[5], kernel_hint[4:2], dataflow[1], sew[0]}
          is 9 bits:  sew(2) precision, dataflow(1), kernel_hint(3), acc_clear(1), rsvd(2)
  uimm5 = TILE_H (feature-map rows mapped per SAU pass)

VSALD   nf[31:29]=0 | mop[28]=broadcast | rs2/len[24:20] | rs1[19:15] |
        funct3=111 | vd[11:7] | opcode=0101011
VSAM    funct7[31:25]=0b0000001 | vs2[24:20] | vs1[19:15] | funct3=000 |
        acc[11:7] | opcode=0101011
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Union

from repro.core.precision import Precision

__all__ = [
    "Dataflow",
    "VSACFG",
    "VSALD",
    "VSAM",
    "Instruction",
    "encode",
    "decode",
    "OPCODE_OP_V",
    "OPCODE_CUSTOM1",
]

OPCODE_OP_V = 0b1010111  # 0x57
OPCODE_CUSTOM1 = 0b0101011  # 0x2B
_FUNCT3_CFG = 0b111
_FUNCT3_LD = 0b111
_FUNCT3_AM = 0b000
_FUNCT7_AM = 0b0000001

_SEW_TO_PRECISION = {0b00: Precision.INT16, 0b01: Precision.INT8, 0b10: Precision.INT4}
_PRECISION_TO_SEW = {v: k for k, v in _SEW_TO_PRECISION.items()}


class Dataflow(enum.IntEnum):
    """Dataflow strategy selected by VSACFG (paper Sec. II-C)."""

    FF = 0  # feature-map-first: spatial tile stationary, halo reuse
    CF = 1  # channel-first: accumulate along input channels inside the SAU


def _field(value: int, width: int, name: str) -> int:
    if not 0 <= value < (1 << width):
        raise ValueError(f"{name}={value} does not fit in {width} bits")
    return value


@dataclass(frozen=True)
class VSACFG:
    """vsacfg rd, zimm9, uimm5 — configure precision/dataflow/tiling."""

    precision: Precision = Precision.INT8
    dataflow: Dataflow = Dataflow.CF
    kernel_hint: int = 0  # log2-ish kernel-size hint for the selector, 3 bits
    acc_clear: bool = True  # clear SAU accumulators at next VSAM burst
    tile_h: int = 4  # uimm5
    rd: int = 0

    @property
    def zimm9(self) -> int:
        sew = _PRECISION_TO_SEW[self.precision]
        return (
            (_field(sew, 2, "sew"))
            | (_field(int(self.dataflow), 1, "dataflow") << 2)
            | (_field(self.kernel_hint, 3, "kernel_hint") << 3)
            | (_field(int(self.acc_clear), 1, "acc_clear") << 6)
        )

    def encode(self) -> int:
        return (
            (1 << 31)
            | (1 << 30)
            | (_field(self.zimm9, 9, "zimm9") << 20)
            | (_field(self.tile_h, 5, "uimm5") << 15)
            | (_FUNCT3_CFG << 12)
            | (_field(self.rd, 5, "rd") << 7)
            | OPCODE_OP_V
        )


@dataclass(frozen=True)
class VSALD:
    """vsald vd, (rs1), len — load from external-memory base ``rs1`` into the
    VRF at ``vd``; broadcast to all lanes when ``broadcast`` else ordered."""

    vd: int
    rs1: int
    length: int = 0  # rs2/len field: number of unified elements (0 => VL)
    broadcast: bool = True

    def encode(self) -> int:
        return (
            (_field(int(self.broadcast), 1, "mop") << 28)
            | (_field(self.length, 5, "len") << 20)
            | (_field(self.rs1, 5, "rs1") << 15)
            | (_FUNCT3_LD << 12)
            | (_field(self.vd, 5, "vd") << 7)
            | OPCODE_CUSTOM1
        )


@dataclass(frozen=True)
class VSAM:
    """vsam acc, vs1, vs2 — systolic MAC: acc[...] += VRF[vs1] @ VRF[vs2]."""

    acc: int  # Acc Addr in VRF
    vs1: int  # inputs base
    vs2: int  # weights base

    def encode(self) -> int:
        return (
            (_FUNCT7_AM << 25)
            | (_field(self.vs2, 5, "vs2") << 20)
            | (_field(self.vs1, 5, "vs1") << 15)
            | (_FUNCT3_AM << 12)
            | (_field(self.acc, 5, "acc") << 7)
            | OPCODE_CUSTOM1
        )


Instruction = Union[VSACFG, VSALD, VSAM]


def encode(inst: Instruction) -> int:
    return inst.encode()


def decode(word: int) -> Instruction:
    if not 0 <= word < (1 << 32):
        raise ValueError("instruction word must be 32-bit")
    opcode = word & 0x7F
    funct3 = (word >> 12) & 0x7
    if opcode == OPCODE_OP_V and funct3 == _FUNCT3_CFG and (word >> 30) & 0x3 == 0b11:
        zimm9 = (word >> 20) & 0x1FF
        sew = zimm9 & 0x3
        if sew not in _SEW_TO_PRECISION:
            raise ValueError(f"reserved sew encoding {sew:#b}")
        return VSACFG(
            precision=_SEW_TO_PRECISION[sew],
            dataflow=Dataflow((zimm9 >> 2) & 0x1),
            kernel_hint=(zimm9 >> 3) & 0x7,
            acc_clear=bool((zimm9 >> 6) & 0x1),
            tile_h=(word >> 15) & 0x1F,
            rd=(word >> 7) & 0x1F,
        )
    if opcode == OPCODE_CUSTOM1 and funct3 == _FUNCT3_LD:
        return VSALD(
            vd=(word >> 7) & 0x1F,
            rs1=(word >> 15) & 0x1F,
            length=(word >> 20) & 0x1F,
            broadcast=bool((word >> 28) & 0x1),
        )
    if opcode == OPCODE_CUSTOM1 and funct3 == _FUNCT3_AM and (word >> 25) == _FUNCT7_AM:
        return VSAM(
            acc=(word >> 7) & 0x1F,
            vs1=(word >> 15) & 0x1F,
            vs2=(word >> 20) & 0x1F,
        )
    raise ValueError(f"not a SPEED custom instruction: {word:#010x}")


def disassemble(word: int) -> str:
    inst = decode(word)
    if isinstance(inst, VSACFG):
        return (
            f"vsacfg x{inst.rd}, e{inst.precision.value}, "
            f"{inst.dataflow.name.lower()}, kh{inst.kernel_hint}, th{inst.tile_h}"
            + (", clr" if inst.acc_clear else "")
        )
    if isinstance(inst, VSALD):
        mode = "bcast" if inst.broadcast else "ord"
        return f"vsald v{inst.vd}, (x{inst.rs1}), n{inst.length}, {mode}"
    return f"vsam v{inst.acc}, v{inst.vs1}, v{inst.vs2}"
