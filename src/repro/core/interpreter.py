"""Functional simulator for SPEED's custom-instruction programs.

Executes the instruction stream emitted by :mod:`repro.core.assembler` against
an architectural model: external memory, per-lane vector register files, the
SAU, and the lane-sequencer counters.  The output of a program must equal the
plain convolution oracle — this is the executable specification of the ISA
semantics (pinned by tests/test_interpreter.py, across precisions, dataflows
and kernel sizes).

Microarchitectural conventions (see assembler docstring for layouts):

  * VRF: 32 vector registers x VLEN=4096 bits per lane; modelled as int32
    operand slots (256 x 16-bit operands per register).  Register *spaces*
    (8 registers each) form contiguous slabs: inputs v0-, weights v8-,
    FF accumulation strips v16- (the paper's "Acc Addr" lives in the VRF),
    CF output-queue drain space v24-.
  * The operand requester's address generator (paper Sec. II-B: "an address
    generator and a request arbiter") sweeps one *accumulate chain* per VSAM:
    the (k*k*g) reduction of one output column for the current input-channel
    stage under FF, or the full (ce*k*k*g) reduction under CF (accumulating
    in the SAU, results drained through the output queue).
  * The lane sequencer keeps a column counter (advanced per VSAM, reset by
    VSALD/VSACFG) and an input-stage counter (advanced per broadcast VSALD,
    reset by VSACFG) — the auto-increment state a systolic sequencer tracks.
  * Transfer lengths/strides come from the layer geometry the scalar core
    programs via CSRs; the 5-bit ``length`` field in VSALD is a debug hint
    (as in RVV, where the real vector length lives in ``vl``/``vtype`` CSRs,
    not in the instruction word).

The simulator is numpy-based (bit-accurate int64 accumulation); the
``bit_accurate`` flag additionally routes every product through the 4-bit
digit decomposition of :func:`repro.core.sau.pe_multiply`, proving the
multi-precision multiplier-combination identity end-to-end.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import sau as sau_mod
from repro.core.assembler import Program, V_ACC, V_IN, V_WT
from repro.core.isa import VSACFG, VSALD, VSAM, Dataflow, decode

__all__ = ["Machine", "run_program"]

_REG_OPS = 256  # 4096-bit register / 16-bit operand slots
_SLAB_REGS = 8


@dataclass
class Machine:
    program: Program
    bit_accurate: bool = False

    # architectural state
    vrf: np.ndarray = field(init=False)  # [lanes, 32, 256] int32 operand slots
    cfg: VSACFG = field(init=False)
    col: int = 0
    stage: int = 0
    in_shape: tuple[int, ...] = ()  # shape of the last input load (per lane)

    def __post_init__(self) -> None:
        hw = self.program.hw
        self.vrf = np.zeros((hw.lanes, 32, _REG_OPS), np.int32)
        self.cfg = VSACFG()

    # -- register-space helpers ---------------------------------------------
    def _slab(self, reg: int) -> np.ndarray:
        """Contiguous view of the 8-register space starting at ``reg``."""
        return self.vrf[:, reg : reg + _SLAB_REGS].reshape(self.program.hw.lanes, -1)

    def _write_slab(self, reg: int, lane_data: np.ndarray) -> None:
        slab = self._slab(reg)
        n = lane_data.shape[-1]
        if n > slab.shape[-1]:
            raise RuntimeError(
                f"VRF overflow: load of {n} operands exceeds register space "
                f"({slab.shape[-1]}) at v{reg}"
            )
        slab[:, :n] = lane_data
        slab[:, n:] = 0

    # -- instruction semantics ------------------------------------------------
    def _exec_cfg(self, inst: VSACFG) -> None:
        self.cfg = inst
        self.col = 0
        self.stage = 0
        if inst.acc_clear:
            self.vrf[:, V_ACC:] = 0

    def _exec_load(self, inst: VSALD, base: int) -> None:
        prog, hw = self.program, self.program.hw
        mem = prog.memory
        g = self.cfg.precision.spec.ops_per_element
        k = self.cfg.kernel_hint
        tr = self.cfg.tile_h
        if inst.vd == V_WT:
            # ordered allocation: element e -> lane e % lanes (weights)
            ce, oc_par = prog.ce, hw.oc_parallel
            n_elems = ce * k * k * oc_par
            data = mem[base : base + n_elems * g].reshape(n_elems, g)
            per_lane = np.stack(
                [data[l :: hw.lanes].reshape(-1) for l in range(hw.lanes)]
            )
            self._write_slab(V_WT, per_lane)
            return
        # broadcast input load; geometry-driven 2-D pattern
        w_pad, h_pad = prog.w_pad, prog.h_pad
        rows_full = tr + k - 1
        plane = h_pad * w_pad * g
        row0 = (base - 0) % plane // (w_pad * g) if plane else 0
        rows_avail = min(rows_full, h_pad - row0)
        if self.cfg.dataflow is Dataflow.CF:
            # gather the same row window from every channel plane
            ce = prog.ce
            chunk = np.zeros((ce, rows_full, w_pad, g), np.int32)
            for s in range(ce):
                src = mem[base + s * plane : base + s * plane + rows_avail * w_pad * g]
                chunk[s, :rows_avail] = src.reshape(rows_avail, w_pad, g)
            self.in_shape = chunk.shape
        else:
            chunk = np.zeros((rows_full, w_pad, g), np.int32)
            src = mem[base : base + rows_avail * w_pad * g]
            chunk[:rows_avail] = src.reshape(rows_avail, w_pad, g)
            self.in_shape = chunk.shape
            self.stage += 1
        flat = chunk.reshape(-1)
        self._write_slab(V_IN, np.broadcast_to(flat, (hw.lanes, flat.size)))
        self.col = 0

    def _products(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Element products, optionally through the 4-bit digit identity."""
        if self.bit_accurate:
            import jax.numpy as jnp

            p = sau_mod.pe_multiply(jnp.asarray(a), jnp.asarray(b), self.cfg.precision)
            return np.asarray(p, np.int64)
        return a.astype(np.int64) * b.astype(np.int64)

    def _exec_mac(self, inst: VSAM) -> None:
        prog, hw = self.program, self.program.hw
        g = self.cfg.precision.spec.ops_per_element
        k, tr, tc = self.cfg.kernel_hint, self.cfg.tile_h, hw.tile_c
        ce = prog.ce
        w_out = prog.layer.w_out
        x = self.col
        self.col += 1
        wts = self._slab(V_WT)[:, : ce * k * k * tc * g].reshape(
            hw.lanes, ce, k, k, tc, g
        )
        if self.cfg.dataflow is Dataflow.FF:
            s = self.stage - 1
            inp = self._slab(V_IN)[0, : int(np.prod(self.in_shape))].reshape(self.in_shape)
            # windows: [tr, k, k, g] for output column x
            win = np.stack(
                [inp[r : r + k, x : x + k, :] for r in range(tr)]
            )  # [tr,k,k,g]
            prod = self._products(win[None, :, :, :, None, :], wts[:, s][:, None, :, :, :, :])
            contrib = prod.sum(axis=(2, 3, 5))  # [lanes, tr, tc]
            strip = self._slab(inst.acc)[:, : tr * w_out * tc].reshape(
                hw.lanes, tr, w_out, tc
            )
            strip[:, :, x, :] = (strip[:, :, x, :].astype(np.int64) + contrib).astype(
                np.int32
            )
        else:  # CF: full reduction inside the SAU, drain via output queue
            inp = self._slab(V_IN)[0, : int(np.prod(self.in_shape))].reshape(self.in_shape)
            win = np.stack(
                [inp[:, r : r + k, x : x + k, :] for r in range(tr)], axis=1
            )  # [ce, tr, k, k, g]
            prod = self._products(
                win[None, :, :, :, :, None, :], wts[:, :, None, :, :, :, :]
            )  # [lanes, ce, tr, k, k, tc, g]
            out = prod.sum(axis=(1, 3, 4, 6))  # [lanes, tr, tc]
            strip = self._slab(inst.acc)[:, : tr * w_out * tc].reshape(
                hw.lanes, tr, w_out, tc
            )
            strip[:, :, x, :] = out.astype(np.int32)

    # -- driver ---------------------------------------------------------------
    def run(self) -> np.ndarray:
        prog, hw = self.program, self.program.hw
        layer = prog.layer
        out = np.zeros((layer.cout, layer.h_out, layer.w_out), np.int64)
        stores = {s.pc: s for s in prog.stores}
        for pc, word in enumerate(prog.words):
            inst = decode(word)
            if isinstance(inst, VSACFG):
                self._exec_cfg(inst)
            elif isinstance(inst, VSALD):
                self._exec_load(inst, prog.rs1_values[pc])
            elif isinstance(inst, VSAM):
                self._exec_mac(inst)
            if pc in stores:
                st = stores[pc]
                tr, tc = self.cfg.tile_h, hw.tile_c
                strip = self._slab(st.reg)[:, : tr * layer.w_out * tc].reshape(
                    hw.lanes, tr, layer.w_out, tc
                )
                for l in range(hw.lanes):
                    for j in range(tc):
                        oc = st.oc0 + l + hw.lanes * j
                        if oc < layer.cout:
                            out[oc, st.row0 : st.row0 + st.rows, :] = strip[
                                l, : st.rows, :, j
                            ]
        return out


def run_program(program: Program, bit_accurate: bool = False) -> np.ndarray:
    return Machine(program, bit_accurate=bit_accurate).run()
