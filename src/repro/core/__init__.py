# SPEED's primary contribution as a composable JAX module: the multi-precision
# ISA (isa), the systolic-array numerical model (sau), FF/CF dataflow mapping
# (dataflow), the conv->program assembler + functional simulator (assembler,
# interpreter), and the calibrated performance model (perfmodel).
from repro.core.dataflow import ConvLayer, HardwareGeometry
from repro.core.isa import VSACFG, VSALD, VSAM, Dataflow, decode, encode
from repro.core.precision import Precision
from repro.core.sau import SAU

__all__ = [
    "ConvLayer",
    "HardwareGeometry",
    "VSACFG",
    "VSALD",
    "VSAM",
    "Dataflow",
    "decode",
    "encode",
    "Precision",
    "SAU",
]
