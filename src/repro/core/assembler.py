"""Assembler: convolution layer -> SPEED instruction program.

Generates the VSACFG / VSALD / VSAM stream that maps one quantized conv layer
onto the SAU under the FF or CF dataflow (paper Fig. 2), together with the
external-memory image and the metadata a scalar core would supply (base
addresses in ``rs1``).  Programs execute on :class:`repro.core.interpreter.Machine`
and must produce bit-identical results to the jnp convolution oracle — that
equivalence is the executable specification of the custom ISA and is pinned
by ``tests/test_interpreter.py``.

Memory / register conventions (documented simplifications of the 5-page
paper's informal spec):

  * External memory is an int32 word array; a *unified element* is ``g``
    consecutive operand words (g = ops_per_element: 1/4/16 at 16/8/4-bit).
  * Input image layout: ``[ce][h_pad][w_pad][g]`` (channel-major elements).
  * Weight layout: ``[ce][ky][kx][oc][g]`` with oc fastest-varying across
    elements so the *ordered* VSALD interleave (element e -> lane e % L)
    deals output channel oc to lane oc % L — output-channel parallelism
    across lanes, as in Sec. II-B.
  * v0..v7: input operand space; v8..v15: weights; v16..v23: FF accumulation
    strips (Acc Addr, lives in the VRF per the paper); v24..v31: CF output
    queue drain space.
  * The operand requester's address generator (Sec. II-B) sweeps the
    per-chain access pattern, so ONE VSAM covers one accumulate chain:
    FF: the (k x k x g) reduction of one output column at the current
    input-channel stage; CF: the full (ce x k x k x g) reduction of one
    output column.  Stage/column counters advance exactly as the lane
    sequencer would (see interpreter).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.dataflow import ConvLayer, HardwareGeometry
from repro.core.isa import VSACFG, VSALD, VSAM, Dataflow, Instruction
from repro.core.precision import Precision

__all__ = ["Program", "StoreRec", "assemble_conv"]

V_IN, V_WT, V_ACC, V_OUT = 0, 8, 16, 24


@dataclass(frozen=True)
class StoreRec:
    """Stand-in for the standard RVV store (VSE) draining results to memory:
    after instruction ``pc``, store the [tile_r, w_out, tile_c] strip at
    register ``reg`` to output rows ``row0:row0+rows`` of oc tile ``oc0``."""

    pc: int
    reg: int
    row0: int
    rows: int
    oc0: int


@dataclass
class Program:
    layer: ConvLayer
    precision: Precision
    dataflow: Dataflow
    hw: HardwareGeometry
    words: list[int] = field(default_factory=list)
    rs1_values: dict[int, int] = field(default_factory=dict)  # pc -> base addr
    stores: list[StoreRec] = field(default_factory=list)
    memory: np.ndarray | None = None  # int32 external memory image

    # geometry the scalar core configures via CSRs (not modelled bit-exactly)
    w_pad: int = 0
    h_pad: int = 0
    ce: int = 0

    def emit(self, inst: Instruction, rs1_value: int | None = None) -> int:
        pc = len(self.words)
        self.words.append(inst.encode())
        if rs1_value is not None:
            self.rs1_values[pc] = rs1_value
        return pc

    @property
    def n_instructions(self) -> int:
        return len(self.words)


def _layout_memory(
    layer: ConvLayer, x: np.ndarray, w: np.ndarray, precision: Precision
) -> tuple[np.ndarray, int, int, int, int]:
    """Builds the external-memory image.  ``x``: [cin, h, w] ints,
    ``w``: [cout, cin, k, k] ints.  Returns (memory, input_base, weight_base,
    ce, w_pad)."""
    g = precision.spec.ops_per_element
    p = layer.padding
    cin_pad = math.ceil(layer.cin / g) * g
    ce = cin_pad // g
    h_pad, w_pad = layer.h + 2 * p, layer.w + 2 * p
    xp = np.zeros((cin_pad, h_pad, w_pad), np.int32)
    xp[: layer.cin, p : p + layer.h, p : p + layer.w] = x
    # [ce][h][w][g]
    x_elems = xp.reshape(ce, g, h_pad, w_pad).transpose(0, 2, 3, 1)
    wp = np.zeros((layer.cout, cin_pad, layer.k, layer.k), np.int32)
    wp[:, : layer.cin] = w
    # [ce][ky][kx][oc][g]
    w_elems = wp.reshape(layer.cout, ce, g, layer.k, layer.k).transpose(1, 3, 4, 0, 2)
    mem = np.concatenate([x_elems.reshape(-1), w_elems.reshape(-1)])
    return mem.astype(np.int32), 0, x_elems.size, ce, w_pad


def assemble_conv(
    layer: ConvLayer,
    x: np.ndarray,
    w: np.ndarray,
    precision: Precision,
    dataflow: Dataflow,
    hw: HardwareGeometry | None = None,
) -> Program:
    """Assembles the full instruction program computing ``conv(x, w)`` int32."""
    hw = hw or HardwareGeometry()
    prog = Program(layer=layer, precision=precision, dataflow=dataflow, hw=hw)
    mem, in_base, wt_base, ce, w_pad = _layout_memory(layer, x, w, precision)
    prog.memory = mem
    prog.ce = ce
    prog.w_pad = w_pad
    prog.h_pad = layer.h + 2 * layer.padding
    g = precision.spec.ops_per_element
    k, tr = layer.k, hw.tile_r
    rows_per_load = tr + k - 1
    oc_par = hw.oc_parallel
    oc_tiles = math.ceil(layer.cout / oc_par)
    h_tiles = math.ceil(layer.h_out / tr)
    kernel_hint = min(k, 7)
    w_elems_per_octile = ce * k * k * oc_par  # one g-group element per (ce,ky,kx,oc)

    for ot in range(oc_tiles):
        oc0 = ot * oc_par
        # -- weights for this oc tile: ordered allocation deals oc -> lanes --
        # memory is [ce][ky][kx][oc][g]; slice the oc range via strided copy:
        # for simplicity the assembler materializes the slice contiguously at
        # a staging address (a scalar-core memcpy in a real system).
        stage_base = len(prog.memory)
        n_wt = ce * k * k * layer.cout * g
        wview = prog.memory[wt_base : wt_base + n_wt].reshape(ce, k, k, layer.cout, g)
        blk = wview[:, :, :, oc0 : oc0 + oc_par, :]
        if blk.shape[3] < oc_par:  # ragged last oc tile: zero-pad channels
            pad = np.zeros((ce, k, k, oc_par - blk.shape[3], g), np.int32)
            blk = np.concatenate([blk, pad], axis=3)
        stage = np.ascontiguousarray(blk).reshape(-1)
        prog.memory = np.concatenate([prog.memory, stage])
        prog.emit(
            VSACFG(precision=precision, dataflow=dataflow, kernel_hint=kernel_hint,
                   acc_clear=True, tile_h=tr),
        )
        prog.emit(
            VSALD(vd=V_WT, rs1=1, length=min(w_elems_per_octile, 31), broadcast=False),
            rs1_value=stage_base,
        )
        for ht in range(h_tiles):
            row0 = ht * tr
            rows = min(rows_per_load, prog.h_pad - row0)
            prog.emit(
                VSACFG(precision=precision, dataflow=dataflow,
                       kernel_hint=kernel_hint, acc_clear=True, tile_h=tr)
            )
            if dataflow is Dataflow.FF:
                # stage loop over input-channel elements; partial strip in VRF
                for s in range(ce):
                    base = in_base + (s * prog.h_pad + row0) * w_pad * g
                    prog.emit(
                        VSALD(vd=V_IN, rs1=2, length=min(rows * w_pad, 31), broadcast=True),
                        rs1_value=base,
                    )
                    for _x in range(layer.w_out):
                        prog.emit(VSAM(acc=V_ACC, vs1=V_IN, vs2=V_WT))
                pc = prog.n_instructions - 1
                prog.stores.append(StoreRec(pc=pc, reg=V_ACC, row0=row0,
                                            rows=min(tr, layer.h_out - row0), oc0=oc0))
            else:  # CF: prefetch ALL channel elements, accumulate inside SAU
                base = in_base + row0 * w_pad * g
                prog.emit(
                    VSALD(vd=V_IN, rs1=2, length=min(ce * rows * w_pad, 31), broadcast=True),
                    rs1_value=base,
                )
                for _x in range(layer.w_out):
                    prog.emit(VSAM(acc=V_OUT, vs1=V_IN, vs2=V_WT))
                pc = prog.n_instructions - 1
                prog.stores.append(StoreRec(pc=pc, reg=V_OUT, row0=row0,
                                            rows=min(tr, layer.h_out - row0), oc0=oc0))
    return prog
