"""Mixture-of-Experts layer with expert parallelism.

Experts are sharded over the ``model`` mesh axis.  Two dispatch modes:

  * ``replicated`` (default) — activations are replicated over the model axis
    (the TP-style layout this framework uses between attention/MLP blocks), so
    no token movement is needed: each model shard locally builds the
    [E_local, capacity, D] buffers for ITS experts from the full local token
    set, runs the grouped expert FFN, and the partial outputs combine with one
    psum over 'model' — the same collective cost as a TP all-reduce, zero
    all-to-all.  Compile-robust at 384 experts x 512 devices.

  * ``alltoall`` — classic GShard/Switch token routing under shard_map:
    tokens sort by destination expert shard, jax.lax.all_to_all over 'model'
    moves them to their expert's owner, FFN runs, and a second all_to_all
    returns them.  Moves only top-k * tokens bytes instead of psum's full
    activation — wins when k * capacity_factor << E/TP ratio; exercised by the
    multi-device tests and selectable per arch config.

Routing: softmax gate, top-k, fixed per-expert capacity with token dropping
(Switch-style) and the standard load-balancing auxiliary loss.  Position-in
-expert uses the sort/searchsorted trick — no [T, E] one-hot materializes.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P



def _positions_in_expert(eids: jnp.ndarray, n_experts: int):
    """For flat expert assignments [T*k] returns (pos_in_expert [T*k]).

    Memory-light rank computation: stable-sort assignments, rank = index -
    first-occurrence (via searchsorted on the sorted keys), unsort.
    """
    tk = eids.shape[0]
    order = jnp.argsort(eids, stable=True)
    sorted_e = eids[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    ranks = jnp.arange(tk, dtype=jnp.int32) - first.astype(jnp.int32)
    pos = jnp.zeros((tk,), jnp.int32).at[order].set(ranks)
    return pos


def _route(x2: jnp.ndarray, w_router: jnp.ndarray, top_k: int):
    """x2: [T, D] -> (weights [T,k], eids [T,k], aux_loss scalar, probs [T,E])."""
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32), w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, eids = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e (frac_tokens_e * mean_prob_e)
    e = probs.shape[-1]
    counts = jnp.zeros((e,), jnp.float32).at[eids.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(jnp.sum(counts), 1.0)
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))
    return weights, eids.astype(jnp.int32), aux, probs


def _deq(w, dtype):
    """Expert weights may be multi-precision QTensor dicts (the paper's
    serving path): dequantize in-register; int4 payloads unpack along the
    reduction axis."""
    if isinstance(w, dict):
        from repro.quant.pack import unpack_int4

        data = w["data"]
        if int(w["bits"]) == 4:
            data = unpack_int4(data, axis=-2)
        return data.astype(dtype) * w["scale"].astype(dtype)
    return w.astype(dtype)


def _expert_ffn(buf: jnp.ndarray, wg, wu, wd) -> jnp.ndarray:
    """Grouped SwiGLU FFN: buf [E_loc, C, D] x w* [E_loc, D, F] -> [E_loc, C, D]."""
    gate = jnp.einsum("ecd,edf->ecf", buf, _deq(wg, buf.dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, _deq(wu, buf.dtype))
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(buf.dtype) * up
    return jnp.einsum("ecf,efd->ecd", act, _deq(wd, buf.dtype))


def moe_ffn(
    x: jnp.ndarray,  # [B, S, D] (model-axis replicated)
    params: dict,  # router [D, E]; wg/wu/wd [E, D, F] / [E, F, D] (E sharded)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    mesh_model_axis: str = "model",
    model_shards: int = 1,
    dispatch: Literal["replicated", "alltoall"] = "replicated",
    mesh=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out [B, S, D], aux_loss scalar)."""
    if dispatch == "alltoall":
        return _moe_ffn_alltoall(
            x, params, top_k=top_k, capacity_factor=capacity_factor,
            axis=mesh_model_axis, mesh=mesh,
        )
    b, s, d = x.shape
    e = params["router"].shape[-1]
    x2 = x.reshape(-1, d)
    t = x2.shape[0]
    weights, eids, aux, _ = _route(x2, params["router"], top_k)
    cap = int(max(1, (t * top_k * capacity_factor) // e))
    pos = _positions_in_expert(eids.reshape(-1), e).reshape(t, top_k)
    keep = pos < cap

    # Scatter tokens into per-expert buffers [E, cap, D]; each model shard
    # holds the expert-sharded slice of these buffers (XLA partitions the
    # scatter + grouped FFN over the sharded E axis).
    flat_slot = eids * cap + pos  # [T, k]
    flat_slot = jnp.where(keep, flat_slot, 0)
    contrib = jnp.where(keep[..., None], x2[:, None, :], 0.0)  # [T, k, D]
    buf = jnp.zeros((e * cap, d), x.dtype).at[flat_slot.reshape(-1)].add(
        contrib.reshape(-1, d), mode="drop"
    )
    from repro.distributed.sharding import get_mesh, model_axis, shard

    buf = buf.reshape(e, cap, d)
    # Expert dim over 'model' when divisible (kimi: 384/16); otherwise shard
    # the capacity (token) dim over the batch axes — mixtral's E=8 < 16 would
    # otherwise REPLICATE the multi-GB dispatch buffers on every device and
    # drown the step in gathers (§Perf hillclimb #2).
    mesh = get_mesh()
    mx = model_axis()
    ep_ok = mesh is not None and mx is not None and e % mesh.shape[mx] == 0
    if ep_ok:
        buf = shard(buf, "model", None, None)
    else:
        buf = shard(buf, None, "batch", None)
    out_buf = _expert_ffn(buf, params["wg"], params["wu"], params["wd"])
    out_buf = shard(out_buf, "model", None, None) if ep_ok else shard(
        out_buf, None, "batch", None
    )
    gathered = out_buf.reshape(e * cap, d)[flat_slot.reshape(-1)].reshape(t, top_k, d)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    out = jnp.sum(gathered * weights[..., None].astype(x.dtype), axis=1)
    return out.reshape(b, s, d), aux.astype(jnp.float32)


def _moe_ffn_alltoall(
    x: jnp.ndarray,
    params: dict,
    *,
    top_k: int,
    capacity_factor: float,
    axis: str,
    mesh,
):
    """GShard-style token routing under shard_map (see module docstring)."""
    from jax.experimental.shard_map import shard_map

    b, s, d = x.shape
    e = params["router"].shape[-1]
    n_shards = mesh.shape[axis]
    e_loc = e // n_shards
    data_axes = tuple(a for a in mesh.axis_names if a != axis)

    def local_fn(xl, router, wg, wu, wd):
        # xl: [b_loc, s, d] — tokens of MY data shard, replicated over `axis`
        xl2 = xl.reshape(-1, d)
        t = xl2.shape[0]
        weights, eids, aux, _ = _route(xl2, router, top_k)
        cap = int(max(1, (t * top_k * capacity_factor) // e))
        pos = _positions_in_expert(eids.reshape(-1), e).reshape(t, top_k)
        keep = pos < cap
        flat_slot = jnp.where(keep, eids * cap + pos, 0)
        contrib = jnp.where(keep[..., None], xl2[:, None, :], 0.0)
        buf = jnp.zeros((e * cap, d), x.dtype).at[flat_slot.reshape(-1)].add(
            contrib.reshape(-1, d), mode="drop"
        )
        # [n_shards, e_loc * cap, d] -> all_to_all: shard i keeps its experts'
        # buffers from every peer
        buf = buf.reshape(n_shards, e_loc * cap, d)
        recv = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0, tiled=False)
        # recv: [n_shards(peers), e_loc*cap, d] -> merge token sets per expert
        recv = recv.reshape(n_shards, e_loc, cap, d).swapaxes(0, 1)
        recv = recv.reshape(e_loc, n_shards * cap, d)
        out_buf = _expert_ffn(recv, wg, wu, wd)
        out_buf = out_buf.reshape(e_loc, n_shards, cap, d).swapaxes(0, 1)
        back = jax.lax.all_to_all(
            out_buf.reshape(n_shards, e_loc * cap, d), axis, 0, 0, tiled=False
        )
        out_flat = back.reshape(e * cap, d)[flat_slot.reshape(-1)].reshape(t, top_k, d)
        out_flat = jnp.where(keep[..., None], out_flat, 0.0)
        out = jnp.sum(out_flat * weights[..., None].astype(x.dtype), axis=1)
        return out.reshape(xl.shape), aux[None]

    batch_spec = P(data_axes if data_axes else None, None, None)
    aux_spec = P(data_axes if data_axes else None)  # aux differs per data shard
    out, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            batch_spec,
            P(None, None),
            P(axis, None, None),
            P(axis, None, None),
            P(axis, None, None),
        ),
        out_specs=(batch_spec, aux_spec),
        check_rep=False,
    )(x, params["router"], params["wg"], params["wu"], params["wd"])
    return out, jnp.mean(aux)


def init_moe_params(key, d: int, d_ff: int, n_experts: int, dtype=jnp.bfloat16) -> dict:
    import numpy as np

    k1, k2, k3, k4 = jax.random.split(key, 4)
    si, sf = 1.0 / np.sqrt(d), 1.0 / np.sqrt(d_ff)
    return {
        "router": (jax.random.normal(k1, (d, n_experts), jnp.float32) * si).astype(jnp.float32),
        "wg": (jax.random.normal(k2, (n_experts, d, d_ff), jnp.float32) * si).astype(dtype),
        "wu": (jax.random.normal(k3, (n_experts, d, d_ff), jnp.float32) * si).astype(dtype),
        "wd": (jax.random.normal(k4, (n_experts, d_ff, d), jnp.float32) * sf).astype(dtype),
    }
