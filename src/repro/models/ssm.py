"""Mamba2 (SSD — state-space duality) blocks, for mamba2-130m and zamba2-7b.

Implements the SSD scalar-identity formulation (Dao & Gu 2024, arXiv:
2405.21060): per head h with state size N and head dim P,

    h_t = a_t * h_{t-1} + dt_t * (B_t  (x)  x_t)          a_t = exp(dt_t * A_h)
    y_t = C_t . h_t + D_h * x_t

computed CHUNK-PARALLEL: the sequence splits into chunks of length Q; within
a chunk the quadratic "attention-like" term C_i (prod a) B_j^T handles
intra-chunk interactions; a lax.scan over chunks carries the [H, P, N] state
for inter-chunk recurrence — O(S*Q) work, O(S) memory, and the TPU-friendly
matmul-dominated form (the duality the paper is named for).

Decode keeps the [B, H, P, N] state and steps the recurrence in O(1) per
token (`ssd_decode_step`) — this is what makes `long_500k` runnable for the
SSM/hybrid archs where full-attention archs are skipped.

Naming: x/z gating, B/C input/output projections, dt via softplus, grouped
n_groups=1 (B/C shared across heads), following the reference Mamba2 design.
The in/out projections run through the quantized-dense path at serve time
(the paper's multi-precision technique applies to the projection matmuls;
the recurrence itself stays in fp32 — noted in DESIGN.md).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense, dense_init, rms_norm


class SSMDims(NamedTuple):
    d_model: int
    d_inner: int  # = expand * d_model (expand=2)
    n_heads: int  # = d_inner // head_p
    head_p: int  # head dim (P), 64
    state: int  # N


def ssm_dims(d_model: int, state: int, head_p: int = 64, expand: int = 2) -> SSMDims:
    d_inner = expand * d_model
    return SSMDims(d_model, d_inner, d_inner // head_p, head_p, state)


def init_ssm_params(key, dims: SSMDims, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 6)
    d, di, n = dims.d_model, dims.d_inner, dims.state
    return {
        # fused input projection: [z (di), x (di), B (n), C (n), dt (H)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * n + dims.n_heads, dtype),
        "out_proj": dense_init(ks[1], di, d, dtype),
        "A_log": jnp.zeros((dims.n_heads,), jnp.float32),  # A = -exp(A_log)
        "D": jnp.ones((dims.n_heads,), jnp.float32),
        "dt_bias": jnp.full((dims.n_heads,), np.log(np.e - 1), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
    }


def _split_proj(proj: jnp.ndarray, dims: SSMDims):
    di, n, h = dims.d_inner, dims.state, dims.n_heads
    z, x, b, c, dt = jnp.split(proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, x, b, c, dt


def ssd_chunked(
    x: jnp.ndarray,  # [B, S, H, P] f32
    dt: jnp.ndarray,  # [B, S, H] f32 (post-softplus)
    a_log: jnp.ndarray,  # [H]
    b: jnp.ndarray,  # [B, S, N] f32 (shared across heads, n_groups=1)
    c: jnp.ndarray,  # [B, S, N]
    chunk: int = 128,
    return_state: bool = False,
):
    """Chunk-parallel SSD; returns y [B, S, H, P] f32 (and the final
    [B, H, P, N] state when return_state — used by prefill)."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q
    a = -jnp.exp(a_log)  # [H], negative
    loga = dt * a[None, None, :]  # [B, S', H]  log decay per step

    xc = x.reshape(bs, nc, q, h, p).swapaxes(0, 1)  # [nc, B, q, H, P]
    dtc = dt.reshape(bs, nc, q, h).swapaxes(0, 1)
    lac = loga.reshape(bs, nc, q, h).swapaxes(0, 1)
    bc = b.reshape(bs, nc, q, n).swapaxes(0, 1)
    cc = c.reshape(bs, nc, q, n).swapaxes(0, 1)

    def chunk_step(state, xs):
        # state: [B, H, P, N]
        xq, dtq, laq, bq, cq = xs
        cum = jnp.cumsum(laq, axis=1)  # [B, q, H] inclusive log-decay
        total = cum[:, -1]  # [B, H]
        # intra-chunk (attention-like, lower-triangular):
        # L[i, j] = exp(cum_i - cum_j) for i >= j
        li = cum[:, :, None, :] - cum[:, None, :, :]  # [B, q, q, H]
        tri = jnp.tril(jnp.ones((q, q), bool))
        lmat = jnp.where(tri[None, :, :, None], jnp.exp(li), 0.0)
        cb = jnp.einsum("bin,bjn->bij", cq, bq)  # [B, q, q]
        gates = cb[..., None] * lmat * dtq[:, None, :, :]  # [B, i, j, H]
        y_intra = jnp.einsum("bijh,bjhp->bihp", gates, xq)
        # contribution of carried state:
        y_state = jnp.einsum("bin,bhpn,bih->bihp", cq, state, jnp.exp(cum))
        # new state: decayed old + sum_j exp(total - cum_j) dt_j B_j x_j
        w = jnp.exp(total[:, None, :] - cum) * dtq  # [B, q, H]
        ds = jnp.einsum("bjn,bjhp,bjh->bhpn", bq, xq, w)
        state_new = state * jnp.exp(total)[:, :, None, None] + ds
        return state_new, y_intra + y_state

    state0 = jnp.zeros((bs, h, p, n), jnp.float32)
    state_f, ys = jax.lax.scan(chunk_step, state0, (xc, dtc, lac, bc, cc))
    y = ys.swapaxes(0, 1).reshape(bs, nc * q, h, p)[:, :s]
    if return_state:
        return y, state_f
    return y


def ssm_block(params: dict, x_in: jnp.ndarray, dims: SSMDims, chunk: int = 128) -> jnp.ndarray:
    """Full Mamba2 block (pre-norm residual handled by caller): [B,S,D]->[B,S,D]."""
    proj = dense(x_in, params["in_proj"])
    z, xs, b, c, dtr = _split_proj(proj, dims)
    bsz, s = x_in.shape[0], x_in.shape[1]
    xh = xs.astype(jnp.float32).reshape(bsz, s, dims.n_heads, dims.head_p)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    y = ssd_chunked(xh, dt, params["A_log"], b.astype(jnp.float32), c.astype(jnp.float32), chunk)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(bsz, s, dims.d_inner).astype(x_in.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x_in.dtype)  # gated
    y = rms_norm(y, params["norm"].astype(x_in.dtype))
    return dense(y, params["out_proj"])


def ssm_block_with_state(
    params: dict, x_in: jnp.ndarray, dims: SSMDims, chunk: int = 128
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Like :func:`ssm_block` but also returns the final [B,H,P,N] state
    (prefill: the state seeds subsequent O(1) decode steps).  Padded steps
    inside ssd_chunked are state-identities (dt=0 -> decay 1, update 0)."""
    proj = dense(x_in, params["in_proj"])
    z, xs, b, c, dtr = _split_proj(proj, dims)
    bsz, s = x_in.shape[0], x_in.shape[1]
    xh = xs.astype(jnp.float32).reshape(bsz, s, dims.n_heads, dims.head_p)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + params["dt_bias"])
    y, state = ssd_chunked(
        xh, dt, params["A_log"], b.astype(jnp.float32), c.astype(jnp.float32),
        chunk, return_state=True,
    )
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(bsz, s, dims.d_inner).astype(x_in.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x_in.dtype)
    y = rms_norm(y, params["norm"].astype(x_in.dtype))
    return dense(y, params["out_proj"]), state


def ssm_decode_step(
    params: dict,
    x_in: jnp.ndarray,  # [B, 1, D]
    state: jnp.ndarray,  # [B, H, P, N] f32
    dims: SSMDims,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent step; returns ([B, 1, D], new_state)."""
    proj = dense(x_in, params["in_proj"])
    z, xs, b, c, dtr = _split_proj(proj, dims)
    bsz = x_in.shape[0]
    xh = xs.astype(jnp.float32).reshape(bsz, dims.n_heads, dims.head_p)  # S=1 squeezed
    dt = jax.nn.softplus(dtr.astype(jnp.float32).reshape(bsz, dims.n_heads) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a[None, :])  # [B, H]
    bf = b.astype(jnp.float32).reshape(bsz, dims.state)
    cf = c.astype(jnp.float32).reshape(bsz, dims.state)
    upd = jnp.einsum("bn,bhp,bh->bhpn", bf, xh, dt)
    state_new = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cf, state_new) + params["D"][None, :, None] * xh
    y = y.reshape(bsz, 1, dims.d_inner).astype(x_in.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x_in.dtype).reshape(bsz, 1, -1)
    y = rms_norm(y, params["norm"].astype(x_in.dtype))
    return dense(y, params["out_proj"]), state_new
