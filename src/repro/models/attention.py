"""Attention: blockwise-flash for train/prefill, cache attention for decode.

Train/prefill use a pure-JAX flash attention (online softmax over KV blocks
inside lax.scan) so the [S, S] score matrix never materializes — mandatory at
32k+ context and the standard TPU-native formulation (the Pallas analogue on
a real TPU pod swaps in transparently; the dry-run/roofline path needs the
scan form so XLA's SPMD partitioner can reason about it).

Decode attends one query token against the (optionally int8/int4-quantized)
KV cache; sequence-sharded caches reduce via XLA-inserted collectives
(flash-decoding style partial-softmax combine is exposed to the partitioner
through einsum + softmax over the sharded axis).  The Pallas serving kernel
(kernels/mqa_decode.py) implements the same contract for real-TPU serving.

Supports GQA (n_kv_heads < n_heads) and sliding-window masking (gemma3 5:1
local:global, mixtral SWA).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

BLOCK_Q = 512
BLOCK_K = 512
_NEG = -1e30


def _expand_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """[B, S, Hkv, D] -> [B, S, Hkv*groups, D] by repeat (GQA share)."""
    b, s, hkv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, groups, d)).reshape(
        b, s, hkv * groups, d
    )


def flash_attention(
    q: jnp.ndarray,  # [B, Sq, H, D]
    k: jnp.ndarray,  # [B, Sk, Hkv, D]
    v: jnp.ndarray,  # [B, Sk, Hkv, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,  # sliding-window size (None = global)
    q_offset: int = 0,  # absolute position of q[0] (prefill continuation)
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    groups = h // hkv
    sm = 1.0 / jnp.sqrt(jnp.float32(d))
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    # pad to block multiples
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // bq, k.shape[1] // bk

    kg = _expand_kv(k, groups)  # [B, Sk', H, D]
    vg = _expand_kv(v, groups)
    qb = q.reshape(b, nq, bq, h, d).astype(jnp.float32)
    kb = kg.reshape(b, nk, bk, h, d).swapaxes(0, 1)  # [nk, B, bk, H, D]
    vb = vg.reshape(b, nk, bk, h, d).swapaxes(0, 1)

    q_pos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (nq, bq), 0) * bq + jax.lax.broadcasted_iota(jnp.int32, (nq, bq), 1)  # [nq, bq]

    def kv_step(carry, xs):
        m, l, acc = carry  # [B, nq, bq, H], same, [B, nq, bq, H, D]
        kc, vc, kidx = xs  # [B, bk, H, D], [B, bk, H, D], scalar
        scores = jnp.einsum("bnqhd,bkhd->bnqhk", qb, kc) * sm  # [B,nq,bq,H,bk]
        k_pos = kidx * bk + jnp.arange(bk, dtype=jnp.int32)  # [bk]
        valid = k_pos[None, None, :] < sk  # mask padded tail
        mask = valid
        if causal:
            mask = mask & (k_pos[None, None, :] <= q_pos[:, :, None])
        if window is not None:
            mask = mask & (q_pos[:, :, None] - k_pos[None, None, :] < window)
        mask_b = mask[None, :, :, None, :]  # [1, nq, bq, 1, bk]
        scores = jnp.where(mask_b, scores, _NEG)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        p = jnp.where(mask_b, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bnqhk,bkhd->bnqhd", p, vc)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nq, bq, h), _NEG, jnp.float32)
    l0 = jnp.zeros((b, nq, bq, h), jnp.float32)
    a0 = jnp.zeros((b, nq, bq, h, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        kv_step, (m0, l0, a0), (kb, vb, jnp.arange(nk, dtype=jnp.int32))
    )
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    out = out.reshape(b, nq * bq, h, d)[:, :sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # [B, 1, H, D]
    k_cache: jnp.ndarray,  # [B, S, Hkv, D]  (bf16, or int8 payload)
    v_cache: jnp.ndarray,
    length: jnp.ndarray,  # [B] or scalar: current cache fill
    *,
    window: Optional[int] = None,
    k_scale: Optional[jnp.ndarray] = None,  # [B, S, Hkv, 1] when quantized
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """One-token attention over the cache.  O(S) memory: scores are [B, H, S].

    With a sequence-sharded cache the einsum/softmax below partition to the
    flash-decoding pattern (partial max/denominator + collective combine) —
    XLA SPMD inserts the reductions over the sharded S axis.
    """
    b, _, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    groups = h // hkv
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale.astype(jnp.float32)
    if v_scale is not None:
        vf = vf * v_scale.astype(jnp.float32)
    qf = q.reshape(b, hkv, groups, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, kf) / jnp.sqrt(jnp.float32(d))
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]  # [1, S]
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32).reshape(-1), (b,))
    mask = pos < length[:, None]
    if window is not None:
        mask = mask & (pos >= (length[:, None] - window))
    scores = jnp.where(mask[:, None, None, :], scores, _NEG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def paged_decode_attention(
    q: jnp.ndarray,  # [B, 1, H, D]
    k_pool: jnp.ndarray,  # [L, P, ps, Hkv, D]  (int8 payload or bf16)
    v_pool: jnp.ndarray,
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    lengths: jnp.ndarray,  # [B] int32 — tokens already in the cache
    layer,  # int32 — which pool layer this block attends against
    new_k: jnp.ndarray,  # [B, Hkv, D] this step's K/V (not yet in the pool)
    new_v: jnp.ndarray,
    *,
    window: Optional[int] = None,
    k_scale: Optional[jnp.ndarray] = None,  # [L, P, ps, Hkv, 1] when quantized
    v_scale: Optional[jnp.ndarray] = None,
    new_k_scale: Optional[jnp.ndarray] = None,  # [B, Hkv, 1]
    new_v_scale: Optional[jnp.ndarray] = None,
    kv_bits: int = 16,
) -> jnp.ndarray:
    """One-token attention straight against the paged KV pool.

    The paged contract of :func:`decode_attention`: instead of a gathered
    contiguous [B, S, Hkv, D] cache view, the kernel walks each row's page
    table and reads only the pages holding its ``lengths[b]`` cached tokens;
    the token being decoded enters the online softmax as an extra term
    (every token attends to itself) so the softmax spans ``lengths + 1``
    positions.  Dispatches to the Pallas kernel on TPU and its slot-scan XLA
    fallback elsewhere (kernels/ops.py::paged_mqa_decode).
    """
    from repro.kernels import ops

    b, _, h, d = q.shape
    out = ops.paged_mqa_decode(
        q.reshape(b, h, d),
        k_pool,
        v_pool,
        k_scale,
        v_scale,
        tables,
        lengths,
        layer,
        new_k,
        new_v,
        new_k_scale,
        new_v_scale,
        kv_bits=kv_bits,
        window=window,
    )
    return out.reshape(b, 1, h, d)


def paged_prefill_attention(
    q: jnp.ndarray,  # [B, C, H, D] — a chunk of C query tokens
    k_pool: jnp.ndarray,  # [L, P, ps, Hkv, Dk]  (int8 payload or bf16)
    v_pool: jnp.ndarray,
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    ctx_lens: jnp.ndarray,  # [B] int32 — tokens already in the pool
    q_lens: jnp.ndarray,  # [B] int32 — valid chunk tokens per row (<= C)
    layer,  # int32 — which pool layer this block attends against
    chunk_k: jnp.ndarray,  # [B, C, Hkv, Dk] this chunk's K/V (not yet pooled)
    chunk_v: jnp.ndarray,
    *,
    window: Optional[int] = None,
    k_scale: Optional[jnp.ndarray] = None,  # [L, P, ps, Hkv, 1] when quantized
    v_scale: Optional[jnp.ndarray] = None,
    chunk_k_scale: Optional[jnp.ndarray] = None,  # [B, C, Hkv, 1]
    chunk_v_scale: Optional[jnp.ndarray] = None,
    kv_bits: int = 16,
) -> jnp.ndarray:
    """Chunked-prefill attention straight against the paged KV pool.

    The chunk analogue of :func:`paged_decode_attention`: chunk token c sits
    at absolute position ``ctx_lens[b] + c``, attends to every pooled token
    before it through the page tables plus the chunk itself causally, and the
    caller scatters the chunk's K/V into its pages afterwards.  Rows whose
    chunk is bucket-padded set ``q_lens[b] < C``; padded rows produce
    garbage outputs the caller slices off.  Dispatches to the Pallas kernel
    on TPU and its slot-scan XLA fallback elsewhere
    (kernels/ops.py::paged_mqa_prefill)."""
    from repro.kernels import ops

    return ops.paged_mqa_prefill(
        q,
        k_pool,
        v_pool,
        k_scale,
        v_scale,
        tables,
        ctx_lens,
        q_lens,
        layer,
        chunk_k,
        chunk_v,
        chunk_k_scale,
        chunk_v_scale,
        kv_bits=kv_bits,
        window=window,
    )


def paged_verify_attention(*args, **kwargs) -> jnp.ndarray:
    """Speculative-verify attention: a verify window (the last emitted
    token + the draft tokens, ``q_lens = n_draft + 1``) *is* a causal
    self-chunk, so this is :func:`paged_prefill_attention` under a second
    name — the verify entry point stays visible in profiles and docs
    (``kernels/ops.py::paged_mqa_verify`` documents the kernel-level
    contract) without duplicating the 15-parameter plumbing."""
    return paged_prefill_attention(*args, **kwargs)
