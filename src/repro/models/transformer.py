"""Model assembly for the whole LM fleet: dense / MoE / SSM / hybrid / VLM /
audio, with train, prefill and decode entry points.

Design notes (these matter at 512 devices):

  * **scan over layers** with stacked parameters — keeps the HLO size
    O(1) in depth, which is what makes 61-81-layer models lower/compile in
    minutes instead of hours at pod scale (MaxText-style).
  * **remat** (jax.checkpoint) per layer with a configurable policy.
  * **heterogeneous patterns without unrolling**: gemma3's 5:1 local:global
    and zamba2's shared-attention-every-6 are expressed as data (per-layer
    window vector / lax.cond on the step index) inside the scan, not as
    Python-unrolled layers.
  * **flash attention** (models/attention.py) everywhere — no [S, S] tensor.
  * **chunked cross-entropy** — no [tokens, vocab] tensor (262k vocabs).
  * **multi-precision serving** (the paper's technique): `quantize_params`
    converts every large matmul weight to int4/int8 QTensors consumed by the
    mpmm path, and the KV cache stores int8 payloads with per-(token, head)
    scales.

Cache layout: dict with stacked-leading-layer-dim arrays; decode steps scan
over layers carrying per-layer cache slices as scan xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    chunked_cross_entropy,
    dense,
    dense_init,
    embed_init,
    quantize_dense_weight,
    rms_norm,
)

Params = dict[str, Any]
_GLOBAL_WINDOW = 1 << 30  # "no window" sentinel (dynamic window arithmetic)


# ================================================================ init ====
def _init_attn(key, cfg: ArchConfig, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "norm1": jnp.ones((d,), jnp.float32),
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }


def _init_mlp(key, cfg: ArchConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "norm2": jnp.ones((d,), jnp.float32),
        "mlp": {
            "wg": dense_init(ks[0], d, f, dtype),
            "wu": dense_init(ks[1], d, f, dtype),
            "wd": dense_init(ks[2], f, d, dtype),
        },
    }


def _init_dense_block(key, cfg: ArchConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {**_init_attn(k1, cfg, dtype), **_init_mlp(k2, cfg, dtype)}


def _init_moe_block(key, cfg: ArchConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p = _init_attn(k1, cfg, dtype)
    p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
    p["moe"] = moe_mod.init_moe_params(k2, cfg.d_model, cfg.d_ff, cfg.n_experts, dtype)
    return p


def _init_ssm_block(key, cfg: ArchConfig, dtype) -> Params:
    dims = ssm_mod.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_p)
    p = ssm_mod.init_ssm_params(key, dims, dtype)
    p["norm1"] = jnp.ones((cfg.d_model,), jnp.float32)
    return p


def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ArchConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype),
        "unembed": dense_init(keys[1], cfg.d_model, cfg.padded_vocab, dtype),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.family in ("dense", "vlm", "audio"):
        params["blocks"] = _stack_init(
            lambda k: _init_dense_block(k, cfg, dtype), keys[2], cfg.n_layers
        )
    elif cfg.family == "moe":
        if cfg.first_dense:
            params["dense_blocks"] = _stack_init(
                lambda k: _init_dense_block(k, cfg, dtype), keys[3], cfg.first_dense
            )
        params["blocks"] = _stack_init(
            lambda k: _init_moe_block(k, cfg, dtype),
            keys[2],
            cfg.n_layers - cfg.first_dense,
        )
    elif cfg.family == "ssm":
        params["blocks"] = _stack_init(
            lambda k: _init_ssm_block(k, cfg, dtype), keys[2], cfg.n_layers
        )
    elif cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers % cfg.attn_every
        params["blocks"] = _stack_init(
            lambda k: _init_ssm_block(k, cfg, dtype),
            keys[2],
            n_groups * cfg.attn_every,
        )
        if rem:
            params["tail"] = _stack_init(
                lambda k: _init_ssm_block(k, cfg, dtype), keys[4], rem
            )
        params["shared"] = _init_dense_block(keys[5], cfg, dtype)
    else:
        raise ValueError(cfg.family)
    return params


# ============================================================ quantize ====
_QUANT_KEYS = {"wq", "wk", "wv", "wo", "wg", "wu", "wd", "in_proj", "out_proj", "unembed"}


def quantize_params(params: Params, bits: int) -> Params:
    """The paper's technique on the serving path: every large matmul weight
    becomes an int4/int8 payload + per-output-channel scale.  Stacked [L, K,
    N] weights quantize layer-wise (vmap).  Embeddings stay bf16 (gather, not
    matmul); norms/router/ssm-vectors stay f32."""

    def walk(tree, under_moe=False):
        out = {}
        for name, leaf in tree.items():
            if isinstance(leaf, dict):
                out[name] = walk(leaf, under_moe or name == "moe")
            elif name in _QUANT_KEYS and getattr(leaf, "ndim", 0) >= 2:
                q = functools.partial(quantize_dense_weight, bits=bits)
                if leaf.ndim == 2:
                    out[name] = q(leaf)
                else:  # stacked: [L, K, N] or moe [L, E, K, N]
                    fn = q
                    for _ in range(leaf.ndim - 2):
                        fn = jax.vmap(fn)
                    out[name] = fn(leaf)
            else:
                out[name] = leaf
        return out

    return walk(params)


# ======================================================== block applies ====
def _attn_block(p, x, positions, cfg: ArchConfig, window) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (attn_out [B,S,D], k, v) — k/v exposed for cache building."""
    from repro.models.layers import apply_rope

    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xn = rms_norm(x, p["norm1"].astype(x.dtype), cfg.norm_eps)
    q = dense(xn, p["wq"]).reshape(b, s, h, hd)
    k = dense(xn, p["wk"]).reshape(b, s, kv, hd)
    v = dense(xn, p["wv"]).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "model", None)
    o = attn_mod.flash_attention(q, k, v, causal=True, window=window)
    o = dense(o.reshape(b, s, h * hd), p["wo"])
    return shard(o, "batch", None, None), k, v


def _mlp_block(p, x, cfg: ArchConfig) -> jnp.ndarray:
    xn = rms_norm(x, p["norm2"].astype(x.dtype), cfg.norm_eps)
    g = dense(xn, p["mlp"]["wg"])
    u = dense(xn, p["mlp"]["wu"])
    g = shard(g, "batch", None, "model")
    act = (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)) * u
    return shard(dense(act, p["mlp"]["wd"]), "batch", None, None)


def _moe_block(p, x, cfg: ArchConfig, mesh=None) -> tuple[jnp.ndarray, jnp.ndarray]:
    xn = rms_norm(x, p["norm2"].astype(x.dtype), cfg.norm_eps)
    out, aux = moe_mod.moe_ffn(
        xn,
        p["moe"],
        top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
        dispatch=cfg.moe_dispatch,
        mesh=mesh,
    )
    return shard(out, "batch", None, None), aux


def _per_layer_window(cfg: ArchConfig, n: int) -> Optional[jnp.ndarray]:
    """Per-layer dynamic window vector, or None if uniform."""
    if cfg.local_ratio:
        period = cfg.local_ratio + 1
        idx = np.arange(n)
        is_global = (idx + 1) % period == 0
        return jnp.asarray(
            np.where(is_global, _GLOBAL_WINDOW, cfg.window), jnp.int32
        )
    return None


# ============================================================== forward ====
def _embed(params, batch, cfg: ArchConfig) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (x [B,S,D], positions [B,S], loss_mask [B,S])."""
    tokens = batch["tokens"]
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    mask = jnp.ones(tokens.shape, jnp.float32)
    if cfg.prefix_len:
        pre = batch["prefix_emb"].astype(x.dtype)  # [B, P, D] (frontend stub)
        x = jnp.concatenate([pre, x], axis=1)
        mask = jnp.concatenate([jnp.zeros(pre.shape[:2], jnp.float32), mask], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = shard(x, "batch", None, None)
    return x, positions, mask


def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(params: Params, batch, cfg: ArchConfig, mesh=None):
    """Full forward pass -> (hidden [B,S,D], aux_loss, positions, mask)."""
    x, positions, mask = _embed(params, batch, cfg)
    aux_total = jnp.float32(0.0)

    if cfg.family in ("dense", "vlm", "audio"):
        windows = _per_layer_window(cfg, cfg.n_layers)

        def layer(carry, xs):
            x = carry
            p = xs["p"]
            win = xs["win"] if windows is not None else (
                cfg.window if cfg.window else None
            )
            a, _, _ = _attn_block(p, x, positions, cfg, win)
            x = x + a
            x = x + _mlp_block(p, x, cfg)
            return x, None

        xs = {"p": params["blocks"]}
        if windows is not None:
            xs["win"] = windows
        x, _ = jax.lax.scan(_maybe_remat(layer, cfg), x, xs)

    elif cfg.family == "moe":
        def dense_layer(carry, p):
            x = carry
            a, _, _ = _attn_block(p, x, positions, cfg, cfg.window)
            x = x + a
            x = x + _mlp_block(p, x, cfg)
            return x, None

        def moe_layer(carry, p):
            x, aux = carry
            a, _, _ = _attn_block(p, x, positions, cfg, cfg.window)
            x = x + a
            m, aux_l = _moe_block(p, x, cfg, mesh)
            return (x + m, aux + aux_l), None

        if cfg.first_dense:
            x, _ = jax.lax.scan(_maybe_remat(dense_layer, cfg), x, params["dense_blocks"])
        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(moe_layer, cfg), (x, aux_total), params["blocks"]
        )

    elif cfg.family == "ssm":
        dims = ssm_mod.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_p)

        def layer(carry, p):
            x = carry
            xn = rms_norm(x, p["norm1"].astype(x.dtype), cfg.norm_eps)
            x = x + ssm_mod.ssm_block(p, xn, dims)
            return x, None

        x, _ = jax.lax.scan(_maybe_remat(layer, cfg), x, params["blocks"])

    elif cfg.family == "hybrid":
        dims = ssm_mod.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_p)
        n_groups = cfg.n_layers // cfg.attn_every
        shared = params["shared"]

        def ssm_layer(x, p):
            xn = rms_norm(x, p["norm1"].astype(x.dtype), cfg.norm_eps)
            return x + ssm_mod.ssm_block(p, xn, dims)

        def group(carry, p_group):
            x = carry
            def inner(c, p):
                return ssm_layer(c, p), None
            x, _ = jax.lax.scan(inner, x, p_group)
            a, _, _ = _attn_block(shared, x, positions, cfg, cfg.window)
            x = x + a
            x = x + _mlp_block(shared, x, cfg)
            return x, None

        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, cfg.attn_every, *a.shape[1:]),
            params["blocks"],
        )
        x, _ = jax.lax.scan(_maybe_remat(group, cfg), x, grouped)
        if "tail" in params:
            def tail_layer(c, p):
                return ssm_layer(c, p), None
            x, _ = jax.lax.scan(tail_layer, x, params["tail"])
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    return x, aux_total, positions, mask


def train_loss(params: Params, batch, cfg: ArchConfig, mesh=None) -> tuple[jnp.ndarray, dict]:
    h, aux, _, mask = forward(params, batch, cfg, mesh)
    labels = batch["labels"]
    if cfg.prefix_len:  # prefix positions carry no labels
        pad = jnp.zeros((labels.shape[0], cfg.prefix_len), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce = chunked_cross_entropy(h, params["unembed"], labels, mask, vocab=cfg.vocab)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ============================================================== serving ====
def _quantize_token_kv(kv: jnp.ndarray, bits: int):
    """[..., hd] -> (int8 payload, f32 scale[..., 1]) per (token, head).
    bits == 4 bit-packs nibble pairs along hd, so the payload trailing dim is
    hd//2 (matching the int4 page-pool layout)."""
    amax = jnp.maximum(jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1, keepdims=True), 1e-30)
    qmax = float(2 ** (bits - 1) - 1)
    scale = amax / qmax
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale), -qmax - 1, qmax).astype(jnp.int8)
    if bits == 4:
        from repro.quant.pack import pack_int4

        q = pack_int4(q, axis=-1)
    return q, scale.astype(jnp.float32)


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int) -> Params:
    """Pre-allocated decode cache.  KV payloads are int8 when
    cfg.serve_kv_bits < 16 (the paper's multi-precision idea applied to the
    dominant serving memory consumer), bf16 otherwise."""
    kv, hd = cfg.n_kv_heads, cfg.hd
    quant = cfg.serve_kv_bits < 16
    if cfg.serve_kv_bits == 4:
        hd = hd // 2  # nibble-packed payload (the paged serve path unpacks)
    kv_dtype = jnp.int8 if quant else jnp.dtype(cfg.dtype)
    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        n_attn = cfg.n_layers
        cache["k"] = jnp.zeros((n_attn, batch_size, max_len, kv, hd), kv_dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
        if quant:
            cache["k_scale"] = jnp.zeros((n_attn, batch_size, max_len, kv, 1), jnp.float32)
            cache["v_scale"] = jnp.zeros_like(cache["k_scale"])
    elif cfg.family == "ssm":
        dims = ssm_mod.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_p)
        cache["ssm"] = jnp.zeros(
            (cfg.n_layers, batch_size, dims.n_heads, dims.head_p, dims.state), jnp.float32
        )
    elif cfg.family == "hybrid":
        dims = ssm_mod.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_p)
        n_groups = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers % cfg.attn_every
        cache["ssm"] = jnp.zeros(
            (n_groups * cfg.attn_every, batch_size, dims.n_heads, dims.head_p, dims.state),
            jnp.float32,
        )
        if rem:
            cache["ssm_tail"] = jnp.zeros(
                (rem, batch_size, dims.n_heads, dims.head_p, dims.state), jnp.float32
            )
        cache["k"] = jnp.zeros((n_groups, batch_size, max_len, kv, hd), kv_dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
        if quant:
            cache["k_scale"] = jnp.zeros((n_groups, batch_size, max_len, kv, 1), jnp.float32)
            cache["v_scale"] = jnp.zeros_like(cache["k_scale"])
    return cache


def _write_cache_slab(cache_k, kq, pos):
    """Write [B, S_new, ...] at sequence offset pos into [B, S_max, ...]."""
    return jax.lax.dynamic_update_slice_in_dim(cache_k, kq, pos, axis=1)


def prefill(params: Params, batch, cfg: ArchConfig, max_len: int, mesh=None):
    """Processes the full prompt, returns (last-token logits [B, V], cache)."""
    x, positions, _ = _embed(params, batch, cfg)
    b, s, _ = x.shape
    quant = cfg.serve_kv_bits < 16
    cache = init_cache(cfg, b, max_len)

    def fill_kv(k, v):
        if quant:
            kq, ks = _quantize_token_kv(k, cfg.serve_kv_bits)
            vq, vs = _quantize_token_kv(v, cfg.serve_kv_bits)
            return kq, vq, ks, vs
        return k, v, None, None

    if cfg.family in ("dense", "vlm", "audio"):
        windows = _per_layer_window(cfg, cfg.n_layers)

        def layer(carry, xs):
            x = carry
            p = xs["p"]
            win = xs["win"] if windows is not None else (cfg.window if cfg.window else None)
            a, k, v = _attn_block(p, x, positions, cfg, win)
            x = x + a
            x = x + _mlp_block(p, x, cfg)
            return x, fill_kv(k, v)

        xs = {"p": params["blocks"]}
        if windows is not None:
            xs["win"] = windows
        x, kvs = jax.lax.scan(_maybe_remat(layer, cfg), x, xs)
        kq, vq, ks, vs = kvs
        cache["k"] = cache["k"].at[:, :, :s].set(kq)
        cache["v"] = cache["v"].at[:, :, :s].set(vq)
        if quant:
            cache["k_scale"] = cache["k_scale"].at[:, :, :s].set(ks)
            cache["v_scale"] = cache["v_scale"].at[:, :, :s].set(vs)

    elif cfg.family == "moe":
        def dense_layer(carry, p):
            x = carry
            a, k, v = _attn_block(p, x, positions, cfg, cfg.window)
            x = x + a
            x = x + _mlp_block(p, x, cfg)
            return x, fill_kv(k, v)

        def moe_layer(carry, p):
            x = carry
            a, k, v = _attn_block(p, x, positions, cfg, cfg.window)
            x = x + a
            m, _ = _moe_block(p, x, cfg, mesh)
            return x + m, fill_kv(k, v)

        kv_parts = []
        if cfg.first_dense:
            x, kv0 = jax.lax.scan(_maybe_remat(dense_layer, cfg), x, params["dense_blocks"])
            kv_parts.append(kv0)
        x, kv1 = jax.lax.scan(_maybe_remat(moe_layer, cfg), x, params["blocks"])
        kv_parts.append(kv1)
        kvs = jax.tree.map(lambda *a: jnp.concatenate(a, axis=0), *kv_parts) if len(kv_parts) > 1 else kv_parts[0]
        kq, vq, ks, vs = kvs
        cache["k"] = cache["k"].at[:, :, :s].set(kq)
        cache["v"] = cache["v"].at[:, :, :s].set(vq)
        if quant:
            cache["k_scale"] = cache["k_scale"].at[:, :, :s].set(ks)
            cache["v_scale"] = cache["v_scale"].at[:, :, :s].set(vs)

    elif cfg.family == "ssm":
        dims = ssm_mod.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_p)

        def layer(carry, p):
            x = carry
            xn = rms_norm(x, p["norm1"].astype(x.dtype), cfg.norm_eps)
            y, st = ssm_mod.ssm_block_with_state(p, xn, dims)
            return x + y, st

        x, states = jax.lax.scan(_maybe_remat(layer, cfg), x, params["blocks"])
        cache["ssm"] = states

    elif cfg.family == "hybrid":
        dims = ssm_mod.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_p)
        n_groups = cfg.n_layers // cfg.attn_every
        shared = params["shared"]

        def group(carry, p_group):
            x = carry
            def inner(c, p):
                xn = rms_norm(c, p["norm1"].astype(c.dtype), cfg.norm_eps)
                y, st = ssm_mod.ssm_block_with_state(p, xn, dims)
                return c + y, st
            x, sts = jax.lax.scan(inner, x, p_group)
            a, k, v = _attn_block(shared, x, positions, cfg, cfg.window)
            x = x + a
            x = x + _mlp_block(shared, x, cfg)
            return x, (sts, fill_kv(k, v))

        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, cfg.attn_every, *a.shape[1:]),
            params["blocks"],
        )
        x, (sts, kvs) = jax.lax.scan(_maybe_remat(group, cfg), x, grouped)
        cache["ssm"] = sts.reshape(n_groups * cfg.attn_every, *sts.shape[2:])
        kq, vq, ks, vs = kvs
        cache["k"] = cache["k"].at[:, :, :s].set(kq)
        cache["v"] = cache["v"].at[:, :, :s].set(vq)
        if quant:
            cache["k_scale"] = cache["k_scale"].at[:, :, :s].set(ks)
            cache["v_scale"] = cache["v_scale"].at[:, :, :s].set(vs)
        if "tail" in params:
            def tail_layer(c, p):
                xn = rms_norm(c, p["norm1"].astype(c.dtype), cfg.norm_eps)
                y, st = ssm_mod.ssm_block_with_state(p, xn, dims)
                return c + y, st
            x, tsts = jax.lax.scan(tail_layer, x, params["tail"])
            cache["ssm_tail"] = tsts

    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = dense(x[:, -1], params["unembed"]).astype(jnp.float32)
    logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e30)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return logits, cache


def _decode_attn(p, x, cache_slice, pos, cfg: ArchConfig, window):
    """One-layer decode attention: x [B,1,D] + cache slice -> (out, new kv)."""
    from repro.models.layers import apply_rope

    if cfg.serve_kv_bits == 4:
        raise NotImplementedError(
            "int4 KV payloads are nibble-packed; only the paged serve path "
            "(serve/decode.py) unpacks them — use ServeEngine, not decode_step"
        )
    b = x.shape[0]
    kv, hd, h = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    xn = rms_norm(x, p["norm1"].astype(x.dtype), cfg.norm_eps)
    q = dense(xn, p["wq"]).reshape(b, 1, h, hd)
    k = dense(xn, p["wk"]).reshape(b, 1, kv, hd)
    v = dense(xn, p["wv"]).reshape(b, 1, kv, hd)
    posv = jnp.broadcast_to(pos[None, None], (b, 1))
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    quant = cfg.serve_kv_bits < 16
    ck, cv = cache_slice["k"], cache_slice["v"]
    if quant:
        kq, ksc = _quantize_token_kv(k, cfg.serve_kv_bits)
        vq, vsc = _quantize_token_kv(v, cfg.serve_kv_bits)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, kq, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, vq, pos, axis=1)
        cks = jax.lax.dynamic_update_slice_in_dim(cache_slice["k_scale"], ksc, pos, axis=1)
        cvs = jax.lax.dynamic_update_slice_in_dim(cache_slice["v_scale"], vsc, pos, axis=1)
        o = attn_mod.decode_attention(
            q, ck, cv, pos + 1, window=window, k_scale=cks, v_scale=cvs
        )
        new_slice = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, pos, axis=1)
        o = attn_mod.decode_attention(q, ck, cv, pos + 1, window=window)
        new_slice = {"k": ck, "v": cv}
    o = dense(o.reshape(b, 1, h * hd), p["wo"])
    return o, new_slice


def decode_step(params: Params, tokens: jnp.ndarray, cache: Params, cfg: ArchConfig, mesh=None):
    """One decode step: tokens [B, 1] -> (logits [B, V], updated cache)."""
    pos = cache["pos"]
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]  # [B, 1, D]
    b = x.shape[0]
    quant = cfg.serve_kv_bits < 16
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        windows = _per_layer_window(cfg, cfg.n_layers)

        # Decode scans layer-by-layer; MoE models with a leading dense block
        # run the dense prefix unstacked (first_dense is 0 or 1 in practice).
        def layer(carry, xs):
            x = carry
            p, sl = xs["p"], xs["cache"]
            win = xs["win"] if windows is not None else (cfg.window if cfg.window else None)
            a, new_sl = _decode_attn(p, x, sl, pos, cfg, win)
            x = x + a
            if cfg.family == "moe":
                m, _ = _moe_block(p, x, cfg, mesh)
                x = x + m
            else:
                x = x + _mlp_block(p, x, cfg)
            return x, new_sl

        off = 0
        if cfg.family == "moe" and cfg.first_dense:
            for i in range(cfg.first_dense):
                p_i = jax.tree.map(lambda a: a[i], params["dense_blocks"])
                sl = {k: cache[k][i] for k in ("k", "v") if k in cache}
                if quant:
                    sl |= {k: cache[k][i] for k in ("k_scale", "v_scale")}
                a, new_sl = _decode_attn(p_i, x, sl, pos, cfg, cfg.window)
                x = x + a
                x = x + _mlp_block(p_i, x, cfg)
                for k, v_ in new_sl.items():
                    new_cache[k] = new_cache[k].at[i].set(v_)
            off = cfg.first_dense

        xs = {
            "p": params["blocks"],
            "cache": {k: cache[k][off:] for k in (("k", "v", "k_scale", "v_scale") if quant else ("k", "v"))},
        }
        if windows is not None:
            xs["win"] = windows[off:]
        x, new_slices = jax.lax.scan(layer, x, xs)
        for k, v_ in new_slices.items():
            new_cache[k] = new_cache[k].at[off:].set(v_)

    elif cfg.family == "ssm":
        dims = ssm_mod.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_p)

        def layer(carry, xs):
            x = carry
            p, st = xs
            xn = rms_norm(x, p["norm1"].astype(x.dtype), cfg.norm_eps)
            y, st_new = ssm_mod.ssm_decode_step(p, xn, st, dims)
            return x + y, st_new

        x, states = jax.lax.scan(layer, x, (params["blocks"], cache["ssm"]))
        new_cache["ssm"] = states

    elif cfg.family == "hybrid":
        dims = ssm_mod.ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_p)
        n_groups = cfg.n_layers // cfg.attn_every
        shared = params["shared"]
        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, cfg.attn_every, *a.shape[1:]),
            params["blocks"],
        )
        ssm_grouped = cache["ssm"].reshape(n_groups, cfg.attn_every, *cache["ssm"].shape[1:])
        cache_keys = ("k", "v", "k_scale", "v_scale") if quant else ("k", "v")

        def group(carry, xs):
            x = carry
            p_group, sts, sl = xs

            def inner(c, xs2):
                p, st = xs2
                xn = rms_norm(c, p["norm1"].astype(c.dtype), cfg.norm_eps)
                y, st_new = ssm_mod.ssm_decode_step(p, xn, st, dims)
                return c + y, st_new

            x, sts_new = jax.lax.scan(inner, x, (p_group, sts))
            a, new_sl = _decode_attn(shared, x, sl, pos, cfg, cfg.window)
            x = x + a
            x = x + _mlp_block(shared, x, cfg)
            return x, (sts_new, new_sl)

        sl_stack = {k: cache[k] for k in cache_keys}
        x, (sts_new, new_slices) = jax.lax.scan(group, x, (grouped, ssm_grouped, sl_stack))
        new_cache["ssm"] = sts_new.reshape(cache["ssm"].shape)
        for k, v_ in new_slices.items():
            new_cache[k] = v_
        if "tail" in params:
            def tail_layer(c, xs2):
                p, st = xs2
                xn = rms_norm(c, p["norm1"].astype(c.dtype), cfg.norm_eps)
                y, st_new = ssm_mod.ssm_decode_step(p, xn, st, dims)
                return c + y, st_new
            x, tsts = jax.lax.scan(tail_layer, x, (params["tail"], cache["ssm_tail"]))
            new_cache["ssm_tail"] = tsts

    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = dense(x[:, -1], params["unembed"]).astype(jnp.float32)
    logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e30)
    new_cache["pos"] = pos + 1
    return logits, new_cache
