"""Model zoo: CNN benchmark networks (the paper's) + the assigned LM fleet."""
