"""Shared NN building blocks: norms, RoPE, embeddings, dense / quantized dense.

Parameters are plain nested dicts of jnp arrays.  Sharding is by name-pattern
rules (distributed/sharding.py); activations get explicit
with_sharding_constraint at layer boundaries.  The quantized dense layer is
the paper's technique on the serving path: int4/int8 weights (packed, per
-output-channel scales) through kernels.ops.mpmm — Pallas on TPU, XLA dequant
path under dry-run/CPU.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * gamma


def init_rms(d: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


@jax.tree_util.register_static
class StaticBits(int):
    """Quantization bit-width carried in the treedef (static, never traced)."""


def dense(x: jnp.ndarray, w: jnp.ndarray | dict) -> jnp.ndarray:
    """Dense matmul dispatching on plain vs quantized weights.

    Quantized weights are a dict {"data": int payload (packed along K for
    int4), "scale": [1, N] f32, "bits": StaticBits} — the paper's
    multi-precision path.  Uses the XLA dequant route (identical numerics to
    the Pallas kernel, which is validated separately in interpret mode and
    substituted 1:1 on TPU).
    """
    from repro.distributed.sharding import gather_weight

    w = gather_weight(w)
    if isinstance(w, dict):  # quantized
        from repro.kernels.ops import mpmm

        bits = int(w["bits"])
        return mpmm(
            x,
            w["data"],
            w["scale"],
            w_bits=bits,
            mode="dequant" if bits < 16 else "int",
            backend="xla",
        ).astype(x.dtype)
    return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))


def quantize_dense_weight(w: jnp.ndarray, bits: int) -> dict:
    from repro.kernels.ops import pack_weights

    data, scale = pack_weights(w.astype(jnp.float32), bits)
    return {"data": data, "scale": scale, "bits": StaticBits(bits)}


# ------------------------------------------------------------------ RoPE ----
def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, D], positions: [B, S] int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------- chunked cross-entropy ----
def chunked_cross_entropy(
    h: jnp.ndarray,  # [B, S, D] final hidden states
    unembed: jnp.ndarray,  # [D, Vpad]
    labels: jnp.ndarray,  # [B, S] int32
    mask: jnp.ndarray | None = None,  # [B, S]
    vocab: int | None = None,  # real vocab (pad logits masked out)
    max_chunk_elems: int = 1 << 28,
) -> jnp.ndarray:
    """Cross-entropy computed in sequence chunks so the [tokens, V] logits
    tensor never materializes at full length (vocabs here reach 262k).

    Chunk length adapts so one chunk's logits stay under ~max_chunk_elems
    f32 elements (1 GB at the default) regardless of batch/vocab."""
    b, s, d = h.shape
    v = unembed.shape[-1]
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    from repro.distributed.sharding import get_mesh

    mesh = get_mesh()
    n_dev = 1
    if mesh is not None:
        for sz in mesh.shape.values():
            n_dev *= sz
    # budget is per DEVICE: the logits chunk is sharded over the mesh
    n_chunk = max(1, -(-(b * s * v) // (max_chunk_elems * n_dev)))
    while n_chunk < s and s % n_chunk:
        n_chunk += 1
    n_chunk = min(n_chunk, s)
    chunk = s // n_chunk
    hs = h.reshape(b, n_chunk, chunk, d).swapaxes(0, 1)  # [n, B, c, D]
    ls = labels.reshape(b, n_chunk, chunk).swapaxes(0, 1)
    ms = mask.reshape(b, n_chunk, chunk).swapaxes(0, 1)

    def step(carry, xs):
        hc, lc, mc = xs
        logits = jnp.einsum("bcd,dv->bcv", hc, unembed.astype(hc.dtype))
        logits = logits.astype(jnp.float32)
        if vocab is not None and vocab < v:  # mask embedding-table padding
            pad_mask = jnp.arange(v) < vocab
            logits = jnp.where(pad_mask, logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return carry + jnp.sum(nll), None

    total, _ = jax.lax.scan(step, jnp.float32(0.0), (hs, ls, ms))
    return total / jnp.maximum(jnp.sum(mask), 1.0)
