"""Runnable JAX versions of the paper's CNN benchmarks (VGG16 / ResNet18 /
GoogLeNet / SqueezeNet), built from the same layer tables as the cycle model
(models/cnn_zoo.py) and executing every convolution through the
multi-precision conv path (kernels/ops.mpconv) with the mixed FF/CF dataflow
selector — the end-to-end artifact behind examples/cnn_inference_speed.py.

Weights are random (the paper evaluates throughput/efficiency on conv layers,
not accuracy); correctness of each conv is pinned against lax.conv oracles in
the kernel tests.  `run_network` reports the per-layer dataflow the selector
chose so Fig. 3's layer-wise story is directly observable.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.dataflow import ConvLayer
from repro.core.isa import Dataflow
from repro.core.perfmodel import SpeedModel, select_dataflow
from repro.core.precision import Precision
from repro.kernels import ops
from repro.models.cnn_zoo import BENCHMARK_NETWORKS

__all__ = ["init_network", "run_network"]


def init_network(net: str, key, w_bits: int = 8):
    """Random weights for every conv layer, pre-quantized/packed."""
    layers = BENCHMARK_NETWORKS[net]()
    params = []
    for i, l in enumerate(layers):
        k = jax.random.fold_in(key, i)
        w = jax.random.normal(k, (l.k, l.k, l.cin, l.cout), jnp.float32) / (
            l.k * (l.cin ** 0.5)
        )
        params.append(ops.conv_pack_weights(w, w_bits))
    return layers, params


def run_network(
    net: str,
    x: jnp.ndarray,  # [N, H, W, 3]
    params,
    layers: list[ConvLayer],
    *,
    w_bits: int = 8,
    strategy: Literal["ff", "cf", "mixed"] = "mixed",
    interpret: bool | None = None,
):
    """Chains the conv layers (topology simplified to a sequential trace of
    the conv workload: pooling/branching replaced by shape adaptation, since
    the paper's metric covers convolutional layers only).  Returns (activations,
    per-layer dataflow decisions)."""
    model = SpeedModel()
    decisions: list[str] = []
    for layer, (wd, ws) in zip(layers, params):
        # adapt the running activation to this layer's expected input shape
        n = x.shape[0]
        if x.shape[1] != layer.h or x.shape[3] != layer.cin:
            x = jax.image.resize(x, (n, layer.h, layer.w, x.shape[3]), "nearest")
            if x.shape[3] != layer.cin:
                reps = -(-layer.cin // x.shape[3])
                x = jnp.tile(x, (1, 1, 1, reps))[..., : layer.cin]
        if strategy == "mixed":
            df = select_dataflow(layer, Precision.from_bits(w_bits), model)
            dataflow = "ff" if df is Dataflow.FF else "cf"
        else:
            dataflow = strategy
        decisions.append(f"{layer.name}: {dataflow}")
        x = ops.mpconv(
            x,
            wd,
            ws,
            w_bits=w_bits,
            ksize=layer.k,
            stride=layer.stride,
            padding=layer.padding,
            mode="dequant",
            dataflow=dataflow,
            interpret=interpret,
        )
        x = jax.nn.relu(x)
    return x, decisions
