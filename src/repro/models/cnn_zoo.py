"""Convolutional-layer tables for the paper's DNN benchmarks (Sec. III-A):
VGG16, ResNet18, GoogLeNet, SqueezeNet — the paper evaluates area efficiency
"measured across the convolutional layers in the DNN model".

These tables drive core/perfmodel.py (cycle model), benchmarks/fig3.py,
benchmarks/fig4.py and benchmarks/table1.py.  Runnable JAX versions of the
same networks (for the end-to-end quantized-inference example) live in
models/cnn.py and are built from the same tables.
"""
from __future__ import annotations

from repro.core.dataflow import ConvLayer

__all__ = ["vgg16_layers", "resnet18_layers", "googlenet_layers", "squeezenet_layers", "BENCHMARK_NETWORKS"]


def vgg16_layers() -> list[ConvLayer]:
    cfg = [  # (name, cin, cout, hw)
        ("conv1_1", 3, 64, 224), ("conv1_2", 64, 64, 224),
        ("conv2_1", 64, 128, 112), ("conv2_2", 128, 128, 112),
        ("conv3_1", 128, 256, 56), ("conv3_2", 256, 256, 56), ("conv3_3", 256, 256, 56),
        ("conv4_1", 256, 512, 28), ("conv4_2", 512, 512, 28), ("conv4_3", 512, 512, 28),
        ("conv5_1", 512, 512, 14), ("conv5_2", 512, 512, 14), ("conv5_3", 512, 512, 14),
    ]
    return [ConvLayer(n, ci, co, 3, s, s, 1, 1) for n, ci, co, s in cfg]


def resnet18_layers() -> list[ConvLayer]:
    ls: list[ConvLayer] = [ConvLayer("conv1", 3, 64, 7, 224, 224, 2, 3)]
    # (stage, cin, cout, hw_in, first_stride)
    stages = [(1, 64, 64, 56, 1), (2, 64, 128, 56, 2), (3, 128, 256, 28, 2), (4, 256, 512, 14, 2)]
    for st, ci, co, s, stride in stages:
        ls.append(ConvLayer(f"layer{st}.0.conv1", ci, co, 3, s, s, stride, 1))
        so = s // stride
        ls.append(ConvLayer(f"layer{st}.0.conv2", co, co, 3, so, so, 1, 1))
        if stride != 1 or ci != co:
            ls.append(ConvLayer(f"layer{st}.0.down", ci, co, 1, s, s, stride, 0))
        ls.append(ConvLayer(f"layer{st}.1.conv1", co, co, 3, so, so, 1, 1))
        ls.append(ConvLayer(f"layer{st}.1.conv2", co, co, 3, so, so, 1, 1))
    return ls


def googlenet_layers() -> list[ConvLayer]:
    ls = [
        ConvLayer("conv1/7x7", 3, 64, 7, 224, 224, 2, 3),
        ConvLayer("conv2/1x1", 64, 64, 1, 56, 56, 1, 0),
        ConvLayer("conv2/3x3", 64, 192, 3, 56, 56, 1, 1),
    ]
    # (name, cin, hw, b1, [b2s, b2], [b3s, b3], pp)
    inc = [
        ("3a", 192, 28, 64, (96, 128), (16, 32), 32),
        ("3b", 256, 28, 128, (128, 192), (32, 96), 64),
        ("4a", 480, 14, 192, (96, 208), (16, 48), 64),
        ("4b", 512, 14, 160, (112, 224), (24, 64), 64),
        ("4c", 512, 14, 128, (128, 256), (24, 64), 64),
        ("4d", 512, 14, 112, (144, 288), (32, 64), 64),
        ("4e", 528, 14, 256, (160, 320), (32, 128), 128),
        ("5a", 832, 7, 256, (160, 320), (32, 128), 128),
        ("5b", 832, 7, 384, (192, 384), (48, 128), 128),
    ]
    for name, cin, s, b1, (b2s, b2), (b3s, b3), pp in inc:
        ls += [
            ConvLayer(f"inc{name}/1x1", cin, b1, 1, s, s, 1, 0),
            ConvLayer(f"inc{name}/3x3_reduce", cin, b2s, 1, s, s, 1, 0),
            ConvLayer(f"inc{name}/3x3", b2s, b2, 3, s, s, 1, 1),
            ConvLayer(f"inc{name}/5x5_reduce", cin, b3s, 1, s, s, 1, 0),
            ConvLayer(f"inc{name}/5x5", b3s, b3, 5, s, s, 1, 2),
            ConvLayer(f"inc{name}/pool_proj", cin, pp, 1, s, s, 1, 0),
        ]
    return ls


def squeezenet_layers() -> list[ConvLayer]:
    ls = [ConvLayer("conv1", 3, 96, 7, 224, 224, 2, 0)]
    # (name, hw, cin, squeeze, expand)
    fires = [
        ("fire2", 55, 96, 16, 64), ("fire3", 55, 128, 16, 64), ("fire4", 55, 128, 32, 128),
        ("fire5", 27, 256, 32, 128), ("fire6", 27, 256, 48, 192), ("fire7", 27, 384, 48, 192),
        ("fire8", 27, 384, 64, 256), ("fire9", 13, 512, 64, 256),
    ]
    for name, s, cin, sq, ex in fires:
        ls += [
            ConvLayer(f"{name}/squeeze1x1", cin, sq, 1, s, s, 1, 0),
            ConvLayer(f"{name}/expand1x1", sq, ex, 1, s, s, 1, 0),
            ConvLayer(f"{name}/expand3x3", sq, ex, 3, s, s, 1, 1),
        ]
    ls.append(ConvLayer("conv10", 512, 1000, 1, 13, 13, 1, 0))
    return ls


BENCHMARK_NETWORKS = {
    "VGG16": vgg16_layers,
    "ResNet18": resnet18_layers,
    "GoogLeNet": googlenet_layers,
    "SqueezeNet": squeezenet_layers,
}
