"""Modality-frontend STUBS for the [vlm]/[audio] architectures.

Per the assignment, the assigned configs specify the transformer BACKBONE
only; the modality frontend (SigLIP vision tower for paligemma-3b, EnCodec /
T5 conditioning for musicgen-medium) is a stub whose job is to provide
shape/dtype-correct precomputed patch/frame embeddings — both for real
batches (deterministic synthetic features) and for the dry-run's
ShapeDtypeStruct input specs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def prefix_embeddings(cfg: ArchConfig, batch_size: int, seed: int = 0) -> jnp.ndarray:
    """Deterministic synthetic patch/frame embeddings [B, prefix_len, D]."""
    if not cfg.prefix_len:
        raise ValueError(f"{cfg.name} has no modality frontend")
    key = jax.random.fold_in(jax.random.PRNGKey(seed), batch_size)
    x = jax.random.normal(key, (batch_size, cfg.prefix_len, cfg.d_model), jnp.float32)
    return (x / jnp.sqrt(jnp.float32(cfg.d_model))).astype(jnp.dtype(cfg.dtype))


def prefix_spec(cfg: ArchConfig, batch_size: int) -> jax.ShapeDtypeStruct:
    """Dry-run stand-in (no allocation)."""
    return jax.ShapeDtypeStruct(
        (batch_size, cfg.prefix_len, cfg.d_model), jnp.dtype(cfg.dtype)
    )
