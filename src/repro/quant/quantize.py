"""Symmetric linear quantization used throughout the framework.

The paper deploys multi-precision *quantized* DNNs (4/8/16-bit signed int with
per-tensor/per-channel scales); this module is the numerical substrate: scale
computation (absmax calibration), quantize/dequantize, fake-quant for
training-time checks, and the QTensor container the kernels and quantized
layers consume (int4 weights are stored bit-packed, see quant/pack.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.precision import Precision
from repro.quant.pack import pack_int4, unpack_int4

__all__ = [
    "QTensor",
    "absmax_scale",
    "quantize",
    "quantize_per_channel",
    "dequantize",
    "fake_quantize",
]

_STORE_DTYPE = {Precision.INT4: jnp.int8, Precision.INT8: jnp.int8, Precision.INT16: jnp.int16}


@jax.tree_util.register_pytree_node_class
@dataclass
class QTensor:
    """A quantized tensor: integer payload + scale (+ packing metadata).

    ``data`` holds int8/int16 storage; for INT4 the *last axis is bit-packed*
    two-per-byte (length halved) so HBM/VMEM traffic matches SPEED's unified
    elements.  ``scale`` broadcasts against the logical (unpacked) shape.
    """

    data: jnp.ndarray
    scale: jnp.ndarray
    precision: Precision
    packed: bool = False

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.scale), (self.precision, self.packed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scale = children
        precision, packed = aux
        return cls(data=data, scale=scale, precision=precision, packed=packed)

    # -- views --------------------------------------------------------------
    @property
    def logical_shape(self) -> tuple[int, ...]:
        s = list(self.data.shape)
        if self.packed:
            s[-1] *= 2
        return tuple(s)

    def unpacked(self) -> jnp.ndarray:
        """Integer payload with INT4 unpacked to one value per int8."""
        if self.packed:
            return unpack_int4(self.data, axis=-1)
        return self.data

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        return (self.unpacked().astype(dtype) * self.scale.astype(dtype)).astype(dtype)

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize + self.scale.size * 4


def absmax_scale(x: jnp.ndarray, precision: Precision, axis=None, keepdims=True) -> jnp.ndarray:
    """Symmetric absmax scale so that max|x| maps to qmax."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    amax = jnp.maximum(amax, jnp.finfo(jnp.float32).tiny)
    return (amax / precision.spec.qmax).astype(jnp.float32)


def _round_clip(x: jnp.ndarray, precision: Precision) -> jnp.ndarray:
    spec = precision.spec
    q = jnp.clip(jnp.round(x), spec.qmin, spec.qmax)
    return q


def quantize(
    x: jnp.ndarray,
    precision: Precision,
    scale: Optional[jnp.ndarray] = None,
    pack: bool = True,
) -> QTensor:
    """Per-tensor symmetric quantization.  INT4 payloads are bit-packed along
    the last axis when ``pack`` (requires even last-dim)."""
    if scale is None:
        scale = absmax_scale(x, precision)
    q = _round_clip(x / scale, precision).astype(_STORE_DTYPE[precision])
    packed = False
    if precision is Precision.INT4 and pack and q.shape[-1] % 2 == 0:
        q = pack_int4(q, axis=-1)
        packed = True
    return QTensor(data=q, scale=jnp.asarray(scale, jnp.float32), precision=precision, packed=packed)


def quantize_per_channel(
    x: jnp.ndarray,
    precision: Precision,
    channel_axis: int = -1,
    pack: bool = True,
) -> QTensor:
    """Per-channel (typically output-feature) symmetric quantization — what
    the quantized LM layers use for weights."""
    axes = tuple(i for i in range(x.ndim) if i != channel_axis % x.ndim)
    scale = absmax_scale(x, precision, axis=axes, keepdims=True)
    return quantize(x, precision, scale=scale, pack=pack)


def dequantize(q: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    return q.dequantize(dtype)


@partial(jax.jit, static_argnames=("precision", "channel_axis"))
def fake_quantize(x: jnp.ndarray, precision: Precision, channel_axis: Optional[int] = None) -> jnp.ndarray:
    """Quantize-dequantize in one step (straight-through value), used to bound
    quantization error in tests and to emulate deployed precision during
    evaluation."""
    if channel_axis is None:
        scale = absmax_scale(x, precision)
    else:
        axes = tuple(i for i in range(x.ndim) if i != channel_axis % x.ndim)
        scale = absmax_scale(x, precision, axis=axes, keepdims=True)
    return (_round_clip(x / scale, precision) * scale).astype(x.dtype)
