"""Bit-packing for sub-byte operands.

SPEED's unified elements (paper Sec. II-C) pack 16 four-bit operands per
element so one VRF read feeds all sixteen 4-bit multipliers of a PE.  The TPU
analogue is packing two signed int4 operands per int8 byte in HBM/VMEM so one
byte of memory traffic carries two MAC operands — the memory-side half of the
paper's "combine the multipliers" trick.  The Pallas mpmm kernel unpacks
in-register (VMEM) with the same bit ops used here.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["pack_int4", "unpack_int4", "pack_int4_hi_lo"]


def pack_int4(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Packs signed int4 values (stored in an int8 array, range [-8, 7])
    pairwise along ``axis`` into int8 bytes: even index -> low nibble, odd ->
    high nibble.  The packed axis halves in length.
    """
    x = jnp.asarray(x, jnp.int8)
    axis = axis % x.ndim
    if x.shape[axis] % 2 != 0:
        raise ValueError(f"axis {axis} length {x.shape[axis]} must be even to pack")
    lo = jnp.take(x, jnp.arange(0, x.shape[axis], 2), axis=axis)
    hi = jnp.take(x, jnp.arange(1, x.shape[axis], 2), axis=axis)
    return ((hi.astype(jnp.int8) << 4) | (lo.astype(jnp.int8) & 0x0F)).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Inverse of :func:`pack_int4`; returns int8 array of doubled length with
    sign-extended 4-bit values."""
    packed = jnp.asarray(packed, jnp.int8)
    axis = axis % packed.ndim
    # Sign-extend low nibble: shift left then arithmetic shift right.
    lo = (packed.astype(jnp.int8) << 4) >> 4
    hi = packed.astype(jnp.int8) >> 4  # arithmetic shift keeps sign
    stacked = jnp.stack([lo, hi], axis=axis + 1)  # [..., n, 2, ...]
    shape = list(packed.shape)
    shape[axis] *= 2
    return stacked.reshape(shape)


def pack_int4_hi_lo(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Splits wider ints into (hi, lo) 4-bit digit planes (int8 storage):
    ``x == hi * 16 + lo`` with lo in [0, 15] unsigned and hi signed — the
    radix-16 digit decomposition the SAU uses for 8-bit operands
    (see core/sau.py).  Used by the w16/w8 nibble-plane kernels."""
    x = jnp.asarray(x, jnp.int32)
    lo = x & 0x0F
    hi = (x - lo) >> 4
    return hi.astype(jnp.int8), lo.astype(jnp.int8)
