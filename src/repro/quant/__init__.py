"""Quantization substrate: packing, scaling, calibration, quantized layers."""
from repro.quant.pack import pack_int4, unpack_int4, pack_int4_hi_lo
from repro.quant.quantize import (
    QTensor,
    absmax_scale,
    dequantize,
    fake_quantize,
    quantize,
    quantize_per_channel,
)

__all__ = [
    "QTensor",
    "absmax_scale",
    "dequantize",
    "fake_quantize",
    "quantize",
    "quantize_per_channel",
    "pack_int4",
    "unpack_int4",
    "pack_int4_hi_lo",
]
