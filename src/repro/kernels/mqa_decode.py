"""Flash-decode GQA attention over a multi-precision (int8/int4) KV cache.

The serving-side hot spot of the LM fleet: one new token attends to a long
cache.  At 32k-500k context the KV cache dominates HBM traffic, so SPEED's
multi-precision idea is applied where it pays most: keys/values are stored
int8 or bit-packed int4 with per-(token, head) scales and dequantized
in-register, halving/quartering the bytes each decode step must move.

Implementation: classic flash-decoding — grid (batch, kv_head, seq_blocks)
with the sequence dimension innermost/sequential, online-softmax running
(max, denom, acc) state in VMEM scratch, GQA handled by blocking queries as
[groups, head_dim] per kv head.  Length masking supports ragged batches.

Oracle: kernels/ref.py::mqa_decode_ref;  wrapper: kernels/ops.py::mqa_decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mqa_decode_pallas"]

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG_INF = -1e30


def _unpack_kv4(packed: jnp.ndarray) -> jnp.ndarray:
    """[bs, D//2] int8 -> [bs, D] int8 (nibbles packed along head_dim)."""
    lo = (packed << 4) >> 4
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], packed.shape[1] * 2)


def _decode_kernel(
    len_ref,  # [1] int32 (SMEM-ish block)
    q_ref,  # [1, 1, G, D]
    k_ref,  # [1, bs, 1, D or D//2]
    v_ref,  # [1, bs, 1, D or D//2]
    ks_ref,  # [1, bs, 1, 1]
    vs_ref,  # [1, bs, 1, 1]
    o_ref,  # [1, 1, G, D]
    m_ref,  # scratch [G, 1] f32
    l_ref,  # scratch [G, 1] f32
    acc_ref,  # scratch [G, D] f32
    *,
    bs: int,
    kv_bits: int,
    sm_scale: float,
    n_s: int,
):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g, d = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32)  # [G, D]
    k = k_ref[0, :, 0]  # [bs, D(/2)] int8
    v = v_ref[0, :, 0]
    if kv_bits == 4:
        k = _unpack_kv4(k)
        v = _unpack_kv4(v)
    kf = k.astype(jnp.float32) * ks_ref[0, :, 0].astype(jnp.float32)  # [bs, D]
    vf = v.astype(jnp.float32) * vs_ref[0, :, 0].astype(jnp.float32)

    scores = jax.lax.dot_general(
        q, kf, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [G, bs]
    scores = scores * sm_scale
    # ragged-length masking
    pos = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < len_ref[0]  # [1, bs]
    scores = jnp.where(valid, scores, _NEG_INF)

    m_prev = m_ref[...]  # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)  # [G, bs]
    p = jnp.where(valid, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, vf, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def mqa_decode_pallas(
    q: jnp.ndarray,  # [B, Hkv, G, D]
    k_data: jnp.ndarray,  # [B, S, Hkv, D (/2 if kv_bits==4)] int8
    v_data: jnp.ndarray,
    k_scale: jnp.ndarray,  # [B, S, Hkv, 1] f32
    v_scale: jnp.ndarray,
    lengths: jnp.ndarray,  # [B] int32
    *,
    kv_bits: int = 8,
    sm_scale: float,
    bs: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hkv, g, d = q.shape
    s = k_data.shape[1]
    bs = min(bs, s)
    if s % bs:
        # pad-and-mask: callers with non-multiple cache widths (e.g. small
        # page-table widths) get a zero tail that the per-row length mask
        # already excludes — lengths <= s by contract.
        pad = (-s) % bs
        pads = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_data = jnp.pad(k_data, pads)
        v_data = jnp.pad(v_data, pads)
        k_scale = jnp.pad(k_scale, pads)
        v_scale = jnp.pad(v_scale, pads)
        s += pad
    n_s = s // bs
    dk = k_data.shape[-1]
    kernel = functools.partial(
        _decode_kernel, bs=bs, kv_bits=kv_bits, sm_scale=sm_scale, n_s=n_s
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hkv, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, s_: (b_,)),
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, s_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, bs, 1, dk), lambda b_, h_, s_: (b_, s_, h_, 0)),
            pl.BlockSpec((1, bs, 1, dk), lambda b_, h_, s_: (b_, s_, h_, 0)),
            pl.BlockSpec((1, bs, 1, 1), lambda b_, h_, s_: (b_, s_, h_, 0)),
            pl.BlockSpec((1, bs, 1, 1), lambda b_, h_, s_: (b_, s_, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, s_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
        name=f"mqa_decode_kv{kv_bits}",
    )(lengths, q, k_data, v_data, k_scale, v_scale)
