"""jit'd public wrappers around the Pallas kernels.

These handle what the raw kernels don't: padding to block multiples, block
size selection, FF/CF dataflow selection (via the same core.dataflow selector
the conv mapper uses — a matmul is a 1x1 conv), weight packing/quantization,
KV-cache quantization, and platform dispatch (Pallas interpret mode on CPU,
compiled on TPU; an XLA-native fallback is available for A/B tests).
"""
from __future__ import annotations

import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import ConvLayer
from repro.core.isa import Dataflow
from repro.core.precision import Precision
from repro.kernels import mpmm as mpmm_mod
from repro.kernels import mqa_decode as dec_mod
from repro.kernels import paged_decode as paged_mod
from repro.kernels import paged_prefill as paged_prefill_mod
from repro.kernels import ref as ref_mod
from repro.quant.pack import pack_int4

__all__ = [
    "pack_weights",
    "mpmm",
    "select_matmul_dataflow",
    "mpconv",
    "quantize_kv",
    "mqa_decode",
    "paged_mqa_decode",
    "paged_mqa_prefill",
    "paged_mqa_verify",
    "sample_keys",
    "sampling_probs",
    "sample_from_probs",
    "sample_tokens",
]

_INT_DTYPE = {4: jnp.int8, 8: jnp.int8, 16: jnp.int16}


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def pack_weights(w: jnp.ndarray, bits: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-output-channel symmetric quantization of a [K, N] weight matrix.

    Returns (w_data, w_scale): w_data is [K, N] int8/int16, or [K//2, N] int8
    with two K-consecutive nibbles per byte when bits == 4 (SPEED's unified
    elements along the reduction dim); w_scale is [1, N] f32.
    """
    prec = Precision.from_bits(bits)
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=0, keepdims=True), 1e-30)
    scale = (amax / prec.spec.qmax).astype(jnp.float32)
    q = jnp.clip(jnp.round(w / scale), prec.spec.qmin, prec.spec.qmax)
    q = q.astype(_INT_DTYPE[bits])
    if bits == 4:
        q = pack_int4(q, axis=0)
    return q, scale


def select_matmul_dataflow(m: int, n: int, k: int) -> Dataflow:
    """FF/CF selection for a matmul via the conv cost model (1x1 conv with
    cin=k, cout=n, h*w=m)."""
    from repro.core.perfmodel import select_dataflow

    hw = int(np.sqrt(max(m, 1))) or 1
    layer = ConvLayer("mm", cin=k, cout=n, k=1, h=hw, w=max(m // hw, 1))
    return select_dataflow(layer, Precision.INT8)


def _pad_to(x: jnp.ndarray, mult: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, (-s) % m_) for s, m_ in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def _pick_blocks(m: int, n: int, k: int, kpack: int) -> tuple[int, int, int]:
    def shrink(target: int, size: int, align: int) -> int:
        b = min(target, max(align, 1 << (size - 1).bit_length()))
        return max(align, min(b, target))

    bm = shrink(128, m, 8)
    bn = shrink(128, n, 128) if n >= 128 else 128
    bk = shrink(512, k, 128 * kpack)
    return bm, bn, bk


@functools.partial(
    jax.jit,
    static_argnames=("w_bits", "x_bits", "mode", "dataflow", "backend", "interpret"),
)
def mpmm(
    x: jnp.ndarray,
    w_data: jnp.ndarray,
    w_scale: jnp.ndarray,
    *,
    w_bits: int,
    x_bits: int = 16,
    mode: Literal["int", "dequant"] = "dequant",
    dataflow: Literal["ff", "cf", "auto"] = "cf",
    backend: Literal["pallas", "xla"] = "pallas",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Multi-precision matmul: x [..., K] @ dequant(w) -> [..., N].

    int mode returns f32 = int32_acc * w_scale (int arithmetic inside);
    dequant mode returns x.dtype.
    """
    if interpret is None:
        interpret = _interpret_default()
    if mode == "dequant" and w_bits == 16:
        raise ValueError("w16 requires int mode (bf16 cannot hold int16 exactly)")
    lead = x.shape[:-1]
    k_sz = x.shape[-1]
    kpack = 2 if w_bits == 4 else 1
    n_sz = w_data.shape[-1]
    x2 = x.reshape(-1, k_sz)
    m_sz = x2.shape[0]

    if dataflow == "auto":
        dataflow = (
            "ff" if select_matmul_dataflow(m_sz, n_sz, k_sz) is Dataflow.FF else "cf"
        )

    if backend == "xla":
        out = ref_mod.mpmm_ref(x2, w_data, w_scale, w_bits=w_bits, mode=mode)
        if mode == "int":
            out = out.astype(jnp.float32) * w_scale.astype(jnp.float32)
        return out.reshape(*lead, n_sz)

    bm, bn, bk = _pick_blocks(m_sz, n_sz, k_sz, kpack)
    xp = _pad_to(x2, (bm, bk))
    wp = _pad_to(w_data, (bk // kpack, bn))
    sp = _pad_to(w_scale.reshape(1, -1), (1, bn))
    out = mpmm_mod.mpmm_pallas(
        xp,
        wp,
        sp,
        w_bits=w_bits,
        x_bits=x_bits,
        mode=mode,
        dataflow=dataflow,
        bm=bm,
        bn=bn,
        bk=bk,
        interpret=interpret,
    )
    out = out[:m_sz, :n_sz]
    if mode == "int":
        out = out.astype(jnp.float32) * w_scale.astype(jnp.float32)
    elif dataflow == "ff":
        # FF dequant partials arrive as f32 (the kernel's cross-stage
        # accumulator); apply the scale in f32 like the CF kernel does
        # in-VMEM, then cast once to the activation dtype
        out = (out * w_scale.astype(jnp.float32)).astype(x.dtype)
    return out.reshape(*lead, n_sz)


def mpconv(
    x: jnp.ndarray,  # [N, H, W, Cin]
    w_data: jnp.ndarray,  # packed [K*K*Cin (/2), Cout]
    w_scale: jnp.ndarray,  # [1, Cout]
    *,
    w_bits: int,
    ksize: int,
    stride: int = 1,
    padding: int = 0,
    mode: Literal["int", "dequant"] = "dequant",
    dataflow: Literal["ff", "cf", "auto"] = "auto",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Multi-precision convolution = patch extraction + the mpmm kernel.

    On the TPU a direct convolution is executed by the MXU as an implicit
    matmul anyway; the FF/CF dataflow choice survives as the contraction loop
    order of the matmul core (see kernels/mpmm.py docstring).  The dataflow
    selector receives the true conv geometry.
    """
    n, h, w, cin = x.shape
    cout = w_data.shape[-1]
    if dataflow == "auto":
        from repro.core.perfmodel import select_dataflow

        layer = ConvLayer("conv", cin=cin, cout=cout, k=ksize, h=h, w=w,
                          stride=stride, padding=padding)
        df = select_dataflow(layer, Precision.from_bits(w_bits))
        dataflow = "ff" if df is Dataflow.FF else "cf"
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(ksize, ksize),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [N, Ho, Wo, Cin*K*K] with feature order (cin, kh, kw)
    ho, wo = patches.shape[1], patches.shape[2]
    out = mpmm(
        patches.reshape(-1, patches.shape[-1]),
        w_data,
        w_scale,
        w_bits=w_bits,
        x_bits=16 if mode == "int" else 16,
        mode=mode,
        dataflow=dataflow,
        interpret=interpret,
    )
    return out.reshape(n, ho, wo, cout)


def conv_pack_weights(w: jnp.ndarray, bits: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[Kh, Kw, Cin, Cout] float -> packed ([Cin*Kh*Kw (/2), Cout], [1, Cout])
    matching conv_general_dilated_patches' (cin, kh, kw) feature order."""
    kh, kw, cin, cout = w.shape
    wm = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    return pack_weights(wm, bits)


def quantize_kv(
    kv: jnp.ndarray, bits: int = 8
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[B, S, Hkv, D] float -> (int8 payload [B,S,Hkv,D or D//2], scale
    [B,S,Hkv,1]) — per-(token, head) symmetric scales."""
    prec = Precision.from_bits(bits)
    amax = jnp.maximum(jnp.max(jnp.abs(kv), axis=-1, keepdims=True), 1e-30)
    scale = (amax / prec.spec.qmax).astype(jnp.float32)
    q = jnp.clip(jnp.round(kv / scale), prec.spec.qmin, prec.spec.qmax).astype(jnp.int8)
    if bits == 4:
        q = pack_int4(q, axis=-1)
    return q, scale


@functools.partial(jax.jit, static_argnames=("kv_bits", "bs", "interpret"))
def mqa_decode(
    q: jnp.ndarray,  # [B, H, D]
    k_data: jnp.ndarray,
    v_data: jnp.ndarray,
    k_scale: jnp.ndarray,
    v_scale: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    kv_bits: int = 8,
    bs: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Single-token GQA attention against an int8/int4 KV cache."""
    if interpret is None:
        interpret = _interpret_default()
    b, h, d = q.shape
    hkv = k_data.shape[2]
    # non-multiple widths clamp + pad-and-mask inside the kernel
    qg = q.reshape(b, hkv, h // hkv, d)
    out = dec_mod.mqa_decode_pallas(
        qg,
        k_data,
        v_data,
        k_scale,
        v_scale,
        lengths.astype(jnp.int32),
        kv_bits=kv_bits,
        sm_scale=1.0 / float(np.sqrt(d)),
        bs=bs,
        interpret=interpret,
    )
    return out.reshape(b, h, d)


@functools.partial(jax.jit, static_argnames=("kv_bits", "backend", "interpret"))
def paged_mqa_decode(
    q: jnp.ndarray,  # [B, H, D]
    k_pool: jnp.ndarray,  # [L, P, ps, Hkv, D (/2 if kv_bits==4)]
    v_pool: jnp.ndarray,
    k_scale,  # [L, P, ps, Hkv, 1] f32, or None when kv_bits == 16
    v_scale,
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    lengths: jnp.ndarray,  # [B] int32 — tokens already in the cache
    layer,  # int32 — pool layer to attend against
    new_k: jnp.ndarray,  # [B, Hkv, D (/2)] this step's token, not yet stored
    new_v: jnp.ndarray,
    new_k_scale=None,  # [B, Hkv, 1] f32, or None
    new_v_scale=None,
    *,
    kv_bits: int = 8,
    window=None,  # int or traced scalar (per-layer windows come from scan)
    backend: Optional[Literal["pallas", "xla"]] = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Single-token GQA attention straight against the paged KV pool.

    Reads only the pages each row's table points at (up to its length), and
    folds the step's own not-yet-stored token into the online softmax, so no
    contiguous cache view ever materializes.  ``backend=None`` picks the
    Pallas kernel on TPU and the slot-scan XLA fallback elsewhere (compiled
    Pallas-on-CPU isn't a thing; interpret mode is for correctness tests).
    Only ``window``'s *presence* is static — its value may be traced.
    """
    if interpret is None:
        interpret = _interpret_default()
    if backend is None:
        backend = "xla" if jax.default_backend() != "tpu" else "pallas"
    b, h, d = q.shape
    hkv = k_pool.shape[3]
    qg = q.reshape(b, hkv, h // hkv, d)
    sm_scale = 1.0 / float(np.sqrt(d))
    args = (
        qg,
        k_pool,
        v_pool,
        k_scale,
        v_scale,
        tables.astype(jnp.int32),
        lengths.astype(jnp.int32),
        jnp.asarray(layer, jnp.int32),
        new_k,
        new_v,
        new_k_scale,
        new_v_scale,
    )
    if backend == "xla":
        out = paged_mod.paged_mqa_decode_xla(
            *args, kv_bits=kv_bits, sm_scale=sm_scale, window=window
        )
    else:
        out = paged_mod.paged_mqa_decode_pallas(
            *args,
            kv_bits=kv_bits,
            sm_scale=sm_scale,
            window=window,
            interpret=interpret,
        )
    return out.reshape(b, h, d)


@functools.partial(jax.jit, static_argnames=("kv_bits", "backend", "interpret"))
def paged_mqa_prefill(
    q: jnp.ndarray,  # [B, C, H, D] — a chunk of C query tokens per row
    k_pool: jnp.ndarray,  # [L, P, ps, Hkv, D (/2 if kv_bits==4)]
    v_pool: jnp.ndarray,
    k_scale,  # [L, P, ps, Hkv, 1] f32, or None when kv_bits == 16
    v_scale,
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    ctx_lens: jnp.ndarray,  # [B] int32 — tokens already in the pool
    q_lens: jnp.ndarray,  # [B] int32 — valid chunk tokens per row
    layer,  # int32 — pool layer to attend against
    chunk_k: jnp.ndarray,  # [B, C, Hkv, D (/2)] this chunk's K, not yet stored
    chunk_v: jnp.ndarray,
    chunk_k_scale=None,  # [B, C, Hkv, 1] f32, or None
    chunk_v_scale=None,
    *,
    kv_bits: int = 8,
    window=None,  # int or traced scalar (per-layer windows come from scan)
    backend: Optional[Literal["pallas", "xla"]] = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Chunked-prefill GQA attention straight against the paged KV pool.

    Chunk token c (absolute position ctx_lens[b] + c) attends to the pages
    holding each row's ctx_lens[b] cached tokens plus the chunk itself under
    a causal-within-chunk mask; rows may be padded (q_lens < C).  Same
    dispatch contract as :func:`paged_mqa_decode`."""
    if interpret is None:
        interpret = _interpret_default()
    if backend is None:
        backend = "xla" if jax.default_backend() != "tpu" else "pallas"
    b, c, h, d = q.shape
    hkv = k_pool.shape[3]
    # [B, C, H, D] -> [B, Hkv, C, G, D]; chunk K/V -> [B, Hkv, C, Dk]
    qg = q.reshape(b, c, hkv, h // hkv, d).transpose(0, 2, 1, 3, 4)
    t = lambda x: None if x is None else x.transpose(0, 2, 1, 3)
    sm_scale = 1.0 / float(np.sqrt(d))
    args = (
        qg,
        k_pool,
        v_pool,
        k_scale,
        v_scale,
        tables.astype(jnp.int32),
        ctx_lens.astype(jnp.int32),
        q_lens.astype(jnp.int32),
        jnp.asarray(layer, jnp.int32),
        t(chunk_k),
        t(chunk_v),
        t(chunk_k_scale),
        t(chunk_v_scale),
    )
    if backend == "xla":
        out = paged_prefill_mod.paged_mqa_prefill_xla(
            *args, kv_bits=kv_bits, sm_scale=sm_scale, window=window
        )
    else:
        out = paged_prefill_mod.paged_mqa_prefill_pallas(
            *args,
            kv_bits=kv_bits,
            sm_scale=sm_scale,
            window=window,
            interpret=interpret,
        )
    return out.transpose(0, 2, 1, 3, 4).reshape(b, c, h, d)


# --------------------------------------------------------------- sampling ops
# Seeded stochastic sampling for the serving engine.  All four ops are
# row-batched (every request in a decode group carries its own temperature /
# top_k / top_p / PRNG key), run inside the engine's jitted hot paths, and
# reduce EXACTLY to greedy argmax when temperature <= 0 — the engine's
# recompute-on-preemption invariant and the greedy golden streams depend on
# that.  Keys are derived as fold_in(fold_in(PRNGKey(seed), position), salt),
# so the token emitted at stream position p depends only on (seed, p) — never
# on batch composition, bucketing, or how many times the request was
# preempted and replayed.


def sample_keys(seeds: jnp.ndarray, positions: jnp.ndarray, salt: int = 0):
    """[B] seeds + [B] stream positions -> [B, 2] per-row PRNG keys.

    ``salt`` separates the independent draws one emission position needs
    (serve/spec_decode.py uses distinct salts for the draft sample, the
    accept uniform and the residual resample at the same position).
    """
    def mk(s, p):
        k = jax.random.PRNGKey(s)
        k = jax.random.fold_in(k, p)
        return jax.random.fold_in(k, salt)

    return jax.vmap(mk)(
        jnp.asarray(seeds, jnp.uint32), jnp.asarray(positions, jnp.int32)
    )


def _top_kp_mask(
    logits: jnp.ndarray, top_k: jnp.ndarray, top_p: jnp.ndarray
) -> jnp.ndarray:
    """[B, V] keep-mask: top-k by logit rank, then nucleus top-p on the
    renormalized surviving distribution (HF warper order).  top_k <= 0 and
    top_p >= 1 disable their stage; the most probable token always survives.
    One descending argsort drives both stages (sorted-domain ranks and
    cumulative mass), scattered back to vocab order at the end.
    """
    b, v = logits.shape
    k = jnp.clip(jnp.where(top_k > 0, top_k, v), 1, v)
    order = jnp.argsort(logits, axis=-1)[:, ::-1]  # descending
    sl = jnp.take_along_axis(logits, order, axis=-1)
    ranks = jnp.arange(v, dtype=jnp.int32)[None, :]
    keep_k = ranks < k[:, None]
    probs = jax.nn.softmax(jnp.where(keep_k, sl, -jnp.inf), axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep the smallest prefix reaching top_p: a token survives while the
    # mass *before* it is still short of top_p (the first always is).  At
    # the disabled value top_p >= 1 keep everything explicitly — f32 tail
    # mass can round `cum - probs` up to exactly 1.0, which the strict
    # `< 1.0` test would mask, breaking the elided==masked equivalence
    keep_p = ((cum - probs) < top_p[:, None]) | (top_p[:, None] >= 1.0)
    keep = keep_k & keep_p
    rows = jnp.arange(b)
    return jnp.zeros((b, v), bool).at[rows[:, None], order].set(keep)


def _masked_logits(logits, top_k, top_p):
    """Apply the top-k/top-p mask; ``top_k=None`` / ``top_p=None`` elide the
    corresponding stage STATICALLY — a temperature-only sampling graph never
    pays the vocab argsort (the engine passes None when no row in a group
    uses the knob)."""
    if top_k is None and top_p is None:
        return logits
    b = logits.shape[0]
    if top_k is None:
        top_k = jnp.zeros(b, jnp.int32)
    if top_p is None:
        top_p = jnp.ones(b, jnp.float32)
    return jnp.where(_top_kp_mask(logits, top_k, top_p), logits, -jnp.inf)


def sampling_probs(
    logits: jnp.ndarray,  # [B, V] f32
    temperature: jnp.ndarray,  # [B] f32; <= 0 means greedy
    top_k=None,  # [B] i32 (<= 0 disables) or None (statically disabled)
    top_p=None,  # [B] f32 (>= 1 disables) or None (statically disabled)
) -> jnp.ndarray:
    """[B, V] exact per-row sampling distribution after top-k -> top-p ->
    temperature: softmax(masked_logits / temperature), a one-hot at the raw
    argmax for greedy rows.  This is the distribution ``sample_tokens`` draws
    from, and what speculative rejection sampling uses for the accept ratio
    and residual (serve/spec_decode.py)."""
    greedy = temperature <= 0.0
    t = jnp.maximum(temperature, 1e-6)[:, None]
    probs = jax.nn.softmax(_masked_logits(logits, top_k, top_p) / t, axis=-1)
    onehot = jax.nn.one_hot(
        jnp.argmax(logits, axis=-1), logits.shape[-1], dtype=probs.dtype
    )
    return jnp.where(greedy[:, None], onehot, probs)


def _inverse_cdf(probs: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """[B, V] probs + [B] uniforms -> [B] sampled indices.

    Two-level search: block partial sums (one O(V) pass), a cumsum over the
    ~sqrt(V) block totals, then a cumsum inside the one selected block per
    row.  A flat jnp.cumsum over the vocab axis lowers to an O(V^2)-ish
    reduce-window on CPU XLA (hundreds of us at V=1024 — comparable to a
    small model's whole decode step); the blocked form keeps in-jit sampling
    a <10% overhead on the serving hot path.  Probs needn't be normalized
    (the threshold is scaled by the row total); zero-probability tokens are
    never drawn, so a one-hot row deterministically returns its hot index.
    """
    b, v = probs.shape
    nb = 1 << ((v - 1).bit_length() + 1) // 2  # ~sqrt(V), power of two
    pad = (-v) % nb
    if pad:
        probs = jnp.pad(probs, ((0, 0), (0, pad)))
    vb = probs.shape[1] // nb
    pb = probs.reshape(b, nb, vb)
    cb = jnp.cumsum(jnp.sum(pb, axis=-1), axis=-1)  # [B, nb] block cdf
    r = u * cb[:, -1]  # [B] threshold in un-normalized mass
    blk = jnp.clip(jnp.sum(cb <= r[:, None], axis=-1), 0, nb - 1)
    base = jnp.where(
        blk > 0,
        jnp.take_along_axis(cb, jnp.maximum(blk - 1, 0)[:, None], 1)[:, 0],
        0.0,
    )
    sub = jnp.take_along_axis(pb, blk[:, None, None], axis=1)[:, 0]  # [B, vb]
    cs = base[:, None] + jnp.cumsum(sub, axis=-1)
    off = jnp.clip(jnp.sum(cs <= r[:, None], axis=-1), 0, vb - 1)
    return jnp.clip(blk * vb + off, 0, v - 1).astype(jnp.int32)


def sample_from_probs(probs: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """Categorical draw per row: [B, V] probs + [B, 2] keys -> [B] int32.

    Inverse-CDF with ONE scalar uniform per row — a per-row gumbel field
    would draw B*V PRNG variates, which dominates a small model's decode
    step on CPU.  Zero-probability tokens are never drawn, and a one-hot row
    (greedy) deterministically returns its hot index whatever the key says."""
    u = jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)
    return _inverse_cdf(probs, u)


def sample_tokens(
    logits: jnp.ndarray,  # [B, V] f32
    keys: jnp.ndarray,  # [B, 2] per-row keys (see sample_keys)
    temperature: jnp.ndarray,  # [B] f32; <= 0 means greedy
    top_k=None,  # [B] i32 (<= 0 disables) or None (statically disabled)
    top_p=None,  # [B] f32 (>= 1 disables) or None (statically disabled)
) -> jnp.ndarray:
    """[B] int32 next tokens: greedy rows are the exact raw argmax (bit-equal
    to the pre-sampling engine), sampled rows draw from exactly
    :func:`sampling_probs`' distribution (inverse-CDF over the masked scaled
    softmax, one uniform per row).  The masked and mask-elided graphs draw
    identical tokens for rows whose top_k/top_p are at their disabled values
    (the mask keeps everything and the uniform is key-determined)."""
    greedy = temperature <= 0.0
    t = jnp.maximum(temperature, 1e-6)[:, None]
    masked = _masked_logits(logits, top_k, top_p)
    # unnormalized exp suffices: _inverse_cdf scales its threshold by the
    # row total, saving softmax's divide pass over the vocab
    w = jnp.exp((masked - jnp.max(masked, axis=-1, keepdims=True)) / t)
    u = jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)
    sampled = _inverse_cdf(w, u)
    return jnp.where(
        greedy, jnp.argmax(logits, axis=-1), sampled
    ).astype(jnp.int32)


def paged_mqa_verify(*args, **kwargs) -> jnp.ndarray:
    """Multi-token verify attention for speculative decoding.

    A speculative verify window *is* a causal self-chunk: the window's C
    tokens (the last emitted token + the draft tokens) sit at absolute
    positions ``ctx_lens[b] + c``, attend to every pooled token before the
    window through the page tables, and to each other under the
    causal-within-chunk mask — exactly the :func:`paged_mqa_prefill`
    contract, so no new attention kernel is needed.  The caller scatters the
    window's target-precision K/V into its pages (overwriting the draft
    passes' K/V) and rolls rejected tail positions back host-side via
    ``cache_len`` truncation, so nothing stale is ever attended.
    """
    return paged_mqa_prefill(*args, **kwargs)
