"""Pure-jnp oracles for every Pallas kernel (no pallas imports).

Each oracle defines the exact semantics its kernel must reproduce; the tests
sweep shapes/dtypes/precisions and assert allclose (bit-exact for the integer
paths) against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "gather_pages",
    "mpmm_ref",
    "mpconv_ref",
    "mqa_decode_ref",
    "paged_mqa_decode_ref",
    "paged_mqa_prefill_ref",
]


def gather_pages(pool: jnp.ndarray, tables: jnp.ndarray) -> jnp.ndarray:
    """[L, P, ps, ...] paged pool + [B, W] page tables -> [L, B, W*ps, ...]
    contiguous cache rows.

    The gather oracle for the paged layout: the kernels index the pool in
    place, so nothing on a hot path materializes this view — tests and
    benchmarks use it to compare paged attention against the dense-cache
    oracles above.
    """
    g = pool[:, tables]  # [L, B, W, ps, ...]
    l, b, w, ps = g.shape[:4]
    return g.reshape(l, b, w * ps, *g.shape[4:])


def _unpack_w4_k(packed: jnp.ndarray) -> jnp.ndarray:
    lo = (packed << 4) >> 4
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=1).reshape(packed.shape[0] * 2, packed.shape[1])


def mpmm_ref(
    x: jnp.ndarray,
    w_data: jnp.ndarray,
    w_scale: jnp.ndarray,
    *,
    w_bits: int,
    mode: str = "dequant",
) -> jnp.ndarray:
    """Oracle for kernels/mpmm.py.

    int mode: exact int32 (wraparound mod 2^32, like the 32-bit SAU
    accumulators) WITHOUT scaling — the wrapper scales.
    dequant mode: float matmul of x against dequantized weights, f32 accum,
    per-column scale applied.
    """
    w = _unpack_w4_k(w_data) if w_bits == 4 else w_data
    if mode == "int":
        # int32 accumulation: wraparound mod 2^32, exactly the kernel's (and
        # the 32-bit SAU accumulator's) semantics.
        acc = jax.lax.dot_general(
            x.astype(jnp.int32),
            w.astype(jnp.int32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        return acc
    acc = jax.lax.dot_general(
        x,
        w.astype(x.dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return (acc * w_scale.astype(jnp.float32)).astype(x.dtype)


def mpconv_ref(
    x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, padding: int = 0
) -> jnp.ndarray:
    """NHWC x HWIO integer/float conv oracle (lax.conv in f32/int32)."""
    if jnp.issubdtype(x.dtype, jnp.integer):
        out = jax.lax.conv_general_dilated(
            x.astype(jnp.int32),
            w.astype(jnp.int32),
            (stride, stride),
            [(padding, padding), (padding, padding)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=jnp.int32,
        )
        return out
    return jax.lax.conv_general_dilated(
        x,
        w.astype(x.dtype),
        (stride, stride),
        [(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def mqa_decode_ref(
    q: jnp.ndarray,
    k_data: jnp.ndarray,
    v_data: jnp.ndarray,
    k_scale: jnp.ndarray,
    v_scale: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    sm_scale: float,
) -> jnp.ndarray:
    """Oracle for kernels/mqa_decode.py — single-token GQA attention over a
    quantized KV cache.

    q:        [B, H, D]            (bf16/f32)
    k_data:   [B, S, Hkv, D] int8  (quantized keys)
    v_data:   [B, S, Hkv, D] int8
    k_scale:  [B, S, Hkv, 1] f32   (per-token-per-head scales)
    v_scale:  [B, S, Hkv, 1] f32
    lengths:  [B] int32 — valid cache length per sequence (masking)
    returns:  [B, H, D] in q.dtype
    """
    b, h, d = q.shape
    s, hkv = k_data.shape[1], k_data.shape[2]
    groups = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, groups, d)
    kf = k_data.astype(jnp.float32) * k_scale.astype(jnp.float32)
    vf = v_data.astype(jnp.float32) * v_scale.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, kf) * sm_scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]  # [B, S]
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    return out.reshape(b, h, d).astype(q.dtype)


def paged_mqa_decode_ref(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    k_scale,
    v_scale,
    tables: jnp.ndarray,
    lengths: jnp.ndarray,
    layer,
    new_k: jnp.ndarray,
    new_v: jnp.ndarray,
    new_k_scale=None,
    new_v_scale=None,
    *,
    sm_scale: float,
    window=None,
) -> jnp.ndarray:
    """Oracle for kernels/paged_decode.py — single-token GQA attention over a
    *paged* quantized KV pool, plus the step's own (not-yet-stored) token.

    q:        [B, H, D]
    k_pool:   [L, P, ps, Hkv, D]  int8 payload (pre-unpacked for kv4) or float
    k_scale:  [L, P, ps, Hkv, 1]  f32, or None for 16-bit pools
    tables:   [B, W] int32 — page ids, zero-padded past each row's table
    lengths:  [B] int32 — tokens already in the cache; the new token attends
              at position lengths[b], so the softmax spans lengths[b] + 1
              positions (never empty, even at lengths == 0)
    layer:    which pool layer to read
    new_k:    [B, Hkv, D] payload of this step's token (same dtype as pool)
    returns:  [B, H, D] in q.dtype

    Semantics are gather-based on purpose: pages are collected into the
    contiguous [B, W*ps, ...] view, the new token is inserted at its own
    position, and plain masked softmax runs over it — the exact computation
    the old serve path performed, kept as the bit-reference for the kernel.
    """
    b, h, d = q.shape
    ps, hkv = k_pool.shape[2], k_pool.shape[3]
    w = tables.shape[1]
    s = w * ps
    rows = jnp.arange(b)
    lengths = lengths.astype(jnp.int32)

    def gather(pool, scale, new, new_scale):
        g = pool[layer][tables]  # [B, W, ps, Hkv, *]
        g = g.reshape(b, s, *g.shape[3:]).astype(jnp.float32)
        if scale is not None:
            sc = scale[layer][tables].reshape(b, s, hkv, 1).astype(jnp.float32)
            g = g * sc
        nf = new.astype(jnp.float32)
        if new_scale is not None:
            nf = nf * new_scale.astype(jnp.float32)
        # one spare position so a full table (lengths == W*ps) still has
        # room for this step's token
        g = jnp.pad(g, ((0, 0), (0, 1)) + ((0, 0),) * (g.ndim - 2))
        return g.at[rows, lengths].set(nf)

    kf = gather(k_pool, k_scale, new_k, new_k_scale)
    vf = gather(v_pool, v_scale, new_v, new_v_scale)
    s = s + 1
    total = lengths + 1  # cache + this step's token
    qf = q.astype(jnp.float32).reshape(b, hkv, h // hkv, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, kf) * sm_scale
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    mask = pos < total[:, None]
    if window is not None:
        mask = mask & (pos >= total[:, None] - window)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    return out.reshape(b, h, d).astype(q.dtype)


def paged_mqa_prefill_ref(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    k_scale,
    v_scale,
    tables: jnp.ndarray,
    ctx_lens: jnp.ndarray,
    q_lens: jnp.ndarray,
    layer,
    chunk_k: jnp.ndarray,
    chunk_v: jnp.ndarray,
    chunk_k_scale=None,
    chunk_v_scale=None,
    *,
    sm_scale: float,
    window=None,
) -> jnp.ndarray:
    """Oracle for kernels/paged_prefill.py — a C-token query chunk attending
    to a *paged* quantized KV pool plus the chunk's own (not-yet-stored) K/V.

    q:        [B, C, H, D]
    k_pool:   [L, P, ps, Hkv, D]  int8 payload (pre-unpacked for kv4) or float
    tables:   [B, W] int32 — page ids, zero-padded past each row's table
    ctx_lens: [B] int32 — tokens already materialized; chunk token c sits at
              absolute position ctx_lens[b] + c
    q_lens:   [B] int32 — valid chunk tokens per row (<= C; rest is padding
              whose output rows are unspecified garbage)
    chunk_k:  [B, C, Hkv, D] payload of this chunk (same dtype as pool)
    returns:  [B, C, H, D] in q.dtype

    Semantics are gather-based on purpose: pages are collected into the
    contiguous [B, W*ps, ...] view, the chunk K/V is appended as extra keys
    at positions ctx + j, and one plain masked softmax runs over both — the
    computation chunked prefill must reproduce without the gather.
    """
    b, c, h, d = q.shape
    ps, hkv = k_pool.shape[2], k_pool.shape[3]
    w = tables.shape[1]
    s = w * ps
    ctx_lens = ctx_lens.astype(jnp.int32)
    q_lens = q_lens.astype(jnp.int32)

    def gather(pool, scale, new, new_scale):
        g = pool[layer][tables]  # [B, W, ps, Hkv, *]
        g = g.reshape(b, s, *g.shape[3:]).astype(jnp.float32)
        if scale is not None:
            sc = scale[layer][tables].reshape(b, s, hkv, 1).astype(jnp.float32)
            g = g * sc
        nf = new.astype(jnp.float32)
        if new_scale is not None:
            nf = nf * new_scale.astype(jnp.float32)
        return jnp.concatenate([g, nf], axis=1)  # [B, S + C, Hkv, D]

    kf = gather(k_pool, k_scale, chunk_k, chunk_k_scale)
    vf = gather(v_pool, v_scale, chunk_v, chunk_v_scale)
    cpos = jnp.arange(c, dtype=jnp.int32)
    # absolute position of every key: pool slots then chunk slots
    k_pos = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s)),
         ctx_lens[:, None] + cpos[None, :]], axis=1,
    )  # [B, S + C]
    k_valid = jnp.concatenate(
        [jnp.arange(s, dtype=jnp.int32)[None, :] < ctx_lens[:, None],
         jnp.broadcast_to(cpos[None, :] < q_lens[:, None], (b, c))], axis=1,
    )
    q_pos = ctx_lens[:, None] + cpos[None, :]  # [B, C]
    mask = k_valid[:, None, :] & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        mask = mask & (q_pos[:, :, None] - k_pos[:, None, :] < window)
    qf = q.astype(jnp.float32).reshape(b, c, hkv, h // hkv, d)
    scores = jnp.einsum("bckgd,bskd->bkgcs", qf, kf) * sm_scale
    scores = jnp.where(mask[:, None, None, :, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked padding rows
    out = jnp.einsum("bkgcs,bskd->bkgcd", p, vf)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, c, h, d).astype(q.dtype)
