"""Multi-precision matmul Pallas kernel — the SAU adapted to the TPU MXU.

SPEED's PE combines sixteen 4-bit multipliers into 1x16b / 4x8b / 16x4b MACs
(paper Sec. II-B).  The MXU's native integer granule is int8xint8->int32, so
the TPU-faithful adaptation applies the *same* split-and-combine identity at
radix 256 instead of radix 16:

    v = sum_d plane_d(v) * 256^d      (int8 digit planes, low planes biased)
    x @ w = sum_{d,e} (plane_d(x) @ plane_e(w)) << 8(d+e)   (+ bias terms)

so a 16-bit matmul runs as 4 int8 MXU passes (2 when only one side is 16-bit)
— exactly the paper's "dynamically combined multipliers", one level up.  The
memory-side half of the trick also transfers: int4 weights are bit-packed two
per byte in HBM/VMEM (SPEED's unified elements) and unpacked in-register, so
4-bit weights move half the bytes of int8 and a quarter of bf16.

Dataflows (paper Sec. II-C, mapped from convolution to its matmul core):

  * CF (channel-first)      — grid (m, n, k), k innermost: the full K
    reduction accumulates in a VMEM scratch accumulator (the SAU-internal
    accumulation), one output writeback, no partial-sum traffic.
  * FF (feature-map-first)  — grid (k, m, n), k outermost: each K stage
    revisits the whole output, partial sums spill to the HBM-backed output
    block exactly like SPEED's FF spills partials to the VRF.  Buys maximal
    operand residency per stage; pays partial-sum bandwidth.

`core.dataflow`'s selector chooses per matmul geometry (a matmul is a 1x1
conv).  Block shapes keep the working set in VMEM and the MXU dims 128-aligned.

Modes:
  * int mode    — x is int8/int16, output int32 (bit-exact wraparound mod
    2^32, matching 32-bit SAU accumulators); optional fused per-column scale.
  * dequant mode — x is bf16/f32, int4/int8 weights are dequantized
    in-register and fed to the MXU in the x dtype (production weight-only
    quantized serving: W4A16/W8A16).

Oracle: kernels/ref.py::mpmm_ref;  wrapper: kernels/ops.py::mpmm.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mpmm_pallas", "DEFAULT_BLOCKS"]

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BLOCKS = dict(bm=128, bn=128, bk=512)


def _unpack_w4(packed: jnp.ndarray) -> jnp.ndarray:
    """[bk//2, bn] int8 (two nibbles per byte along K) -> [bk, bn] int8."""
    lo = (packed << 4) >> 4  # arithmetic shifts sign-extend the low nibble
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=1).reshape(packed.shape[0] * 2, packed.shape[1])


def _digit_planes(v: jnp.ndarray, bits: int):
    """Radix-256 digit planes [(int8 array, shift, bias)], value = arr + bias.

    Low planes carry unsigned bytes re-biased into int8 range (arr = byte-128,
    bias = +128) because the MXU multiplies signed int8; the bias terms are
    reconstructed from row/column sums (see _plane_dot)."""
    if bits <= 8:
        return [(v.astype(jnp.int8), 0, 0)]
    assert bits == 16
    v32 = v.astype(jnp.int32)
    lo = (v32 & 0xFF) - 128  # [-128, 127]
    hi = v32 >> 8  # signed high byte
    return [(lo.astype(jnp.int8), 0, 128), (hi.astype(jnp.int8), 8, 0)]


def _plane_dot(x_planes, w_planes, k_len: int) -> jnp.ndarray:
    """sum_{d,e} (x_d + bx)(w_e + bw) << (sx+se), int32 wraparound."""
    out = None
    for xa, sx, bx in x_planes:
        xs = None  # row sums, computed lazily
        for wa, sw, bw in w_planes:
            part = jax.lax.dot_general(
                xa,
                wa,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            if bw:
                if xs is None:
                    xs = jnp.sum(xa.astype(jnp.int32), axis=1, keepdims=True)
                part = part + bw * xs
            if bx:
                ws = jnp.sum(wa.astype(jnp.int32), axis=0, keepdims=True)
                part = part + bx * ws
            if bx and bw:
                part = part + bx * bw * k_len
            shift = sx + sw
            if shift:
                part = part << shift
            out = part if out is None else out + part
    return out


def _load_w(w_ref, w_bits: int) -> jnp.ndarray:
    w = w_ref[...]
    if w_bits == 4:
        w = _unpack_w4(w)
    return w


# ----------------------------------------------------------------- CF kernel
def _mpmm_cf_kernel(
    x_ref, w_ref, s_ref, o_ref, acc_ref, *, w_bits, x_bits, mode, n_k, bk
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _load_w(w_ref, w_bits)
    if mode == "int":
        acc_ref[...] += _plane_dot(
            _digit_planes(x_ref[...], x_bits),
            _digit_planes(w, min(w_bits, 16)),
            k_len=bk,
        )
    else:  # dequant: int weights -> x dtype, MXU dot in float
        x = x_ref[...]
        acc_ref[...] += jax.lax.dot_general(
            x,
            w.astype(x.dtype),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        acc = acc_ref[...]
        if mode == "int":
            o_ref[...] = acc
        else:
            o_ref[...] = (acc * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


# ----------------------------------------------------------------- FF kernel
def _mpmm_ff_kernel(x_ref, w_ref, o_ref, *, w_bits, x_bits, mode, bk):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _load_w(w_ref, w_bits)
    if mode == "int":
        o_ref[...] += _plane_dot(
            _digit_planes(x_ref[...], x_bits),
            _digit_planes(w, min(w_bits, 16)),
            k_len=bk,
        )
    else:
        # the FF output block IS the cross-K-stage accumulator, so it must
        # be f32 (out_shape below): accumulating spilled partials in the
        # bf16 activation dtype loses ~8 mantissa bits per stage and
        # diverges from the CF path's f32 VMEM accumulator at large K
        x = x_ref[...]
        o_ref[...] += jax.lax.dot_general(
            x,
            w.astype(x.dtype),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


def mpmm_pallas(
    x: jnp.ndarray,
    w_data: jnp.ndarray,
    w_scale: jnp.ndarray,
    *,
    w_bits: int,
    x_bits: int = 16,
    mode: Literal["int", "dequant"] = "dequant",
    dataflow: Literal["ff", "cf"] = "cf",
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw kernel entry: shapes must already be multiples of the blocks.

    x: [M, K] (int8/int16 in int mode; bf16/f32 in dequant mode)
    w_data: [K, N] int8/int16, or [K//2, N] int8 bit-packed when w_bits == 4
    w_scale: [1, N] f32 per-output-channel scale (fused only in CF+dequant)

    Returns x.dtype for CF dequant (scale fused in-kernel), f32 for FF
    dequant (unscaled cross-stage accumulator — the wrapper applies the
    scale in f32 and casts), int32 for int mode.
    """
    m_sz, k_sz = x.shape
    n_sz = w_data.shape[-1]
    kpack = 2 if w_bits == 4 else 1
    assert m_sz % bm == 0 and n_sz % bn == 0 and k_sz % bk == 0, (x.shape, w_data.shape)
    assert w_data.shape[0] * kpack == k_sz
    n_k = k_sz // bk
    if mode == "int":
        out_dtype = jnp.int32
        acc_dtype = jnp.int32
    else:
        out_dtype = x.dtype
        acc_dtype = jnp.float32

    if dataflow == "cf":
        grid = (m_sz // bm, n_sz // bn, n_k)
        kernel = functools.partial(
            _mpmm_cf_kernel, w_bits=w_bits, x_bits=x_bits, mode=mode, n_k=n_k, bk=bk
        )
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
                pl.BlockSpec((bk // kpack, bn), lambda m, n, k: (k, n)),
                pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
            out_shape=jax.ShapeDtypeStruct((m_sz, n_sz), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")
            ),
            interpret=interpret,
            name=f"mpmm_cf_w{w_bits}x{x_bits}_{mode}",
        )(x, w_data, w_scale)
        if mode == "int":
            return out  # scale applied by the wrapper (kept integer-pure)
        return out

    # FF: k outermost, output revisited (partial sums spill to the out block).
    # Dequant-mode partials spill at f32 — the caller applies the scale in
    # f32 and casts down, mirroring the CF kernel's f32 VMEM accumulator.
    grid = (n_k, m_sz // bm, n_sz // bn)
    kernel = functools.partial(
        _mpmm_ff_kernel, w_bits=w_bits, x_bits=x_bits, mode=mode, bk=bk
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda k, m, n: (m, k)),
            pl.BlockSpec((bk // kpack, bn), lambda k, m, n: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda k, m, n: (m, n)),
        out_shape=jax.ShapeDtypeStruct((m_sz, n_sz), acc_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "parallel", "parallel")
        ),
        interpret=interpret,
        name=f"mpmm_ff_w{w_bits}x{x_bits}_{mode}",
    )(x, w_data)
    return out
