"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
wrapped by ops.py (jit, padding, dataflow selection, platform dispatch) and
pinned to ref.py (pure-jnp oracle) by tests/test_kernels_*.py in interpret
mode (CPU executes the kernel body; TPU is the lowering target).
"""
