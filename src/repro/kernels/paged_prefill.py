"""Paged chunk-prefill GQA attention: a query *chunk* against the KV pool.

Chunked prefill processes a prompt's uncached suffix ``C`` tokens at a time:
chunk queries (positions ``ctx .. ctx + C``) attend to (a) every token
already materialized in the paged pool — the prefix-cache hit plus earlier
chunks — and (b) the chunk itself, causally.  This is the prefill analogue of
``kernels/paged_decode.py`` (a decode step is a chunk of one):

* grid ``(batch, kv_head, page_slot)`` with the slot dimension innermost and
  sequential; the same scalar-prefetched page-table index map translates
  ``(row, slot) -> page_id`` and clamps dead slots (at/past ``ctx_lens[b]``,
  or wholly below the sliding-window start) to the row's nearest live page so
  they cost neither DMA nor compute — chunk attention traffic scales with the
  tokens actually cached, not table capacity.
* int8/int4 pool payloads dequantize in-register with per-(token, head)
  scales, exactly like the decode kernel.
* the chunk's own K/V (computed this step, not yet in the pool) enters the
  online softmax in the final grid step under a causal-within-chunk mask
  (key j visible to query c iff ``j <= c``), with per-row valid lengths
  ``q_lens`` masking bucket padding; the caller scatters the chunk into its
  pages afterwards.  Cached positions are all ``< ctx`` so causality against
  the pool is automatic; sliding windows mask per (query, key) distance.

``paged_mqa_prefill_xla`` is the CPU/interpret fallback: a ``lax.scan`` over
page slots with ``lax.cond`` slot skipping, then one fused self-chunk update.
Oracle: ``kernels/ref.py::paged_mqa_prefill_ref``; dispatch:
``kernels/ops.py::paged_mqa_prefill``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mqa_decode import _unpack_kv4
from repro.quant.pack import unpack_int4

__all__ = ["paged_mqa_prefill_pallas", "paged_mqa_prefill_xla"]

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG_INF = -1e30


def _prefill_kernel(
    # scalar prefetch
    tables_ref,  # [B, W] int32
    ctx_ref,  # [B] int32 — tokens already in the pool
    qlen_ref,  # [B] int32 — valid chunk tokens (<= C; rest is padding)
    win_lo_ref,  # [B] int32 — first in-window pool position (0 if no window)
    win_ref,  # [1] int32 — window size (may be traced; 0 when has_window=False)
    layer_ref,  # [1] int32
    # blocks
    q_ref,  # [1, 1, C, G, D]
    k_ref,  # [1, 1, ps, 1, Dk]   (one page of one kv head)
    v_ref,
    *rest,  # [ks_ref, vs_ref,] ck_ref, cv_ref, [cks_ref, cvs_ref,] o_ref + scratch
    ps: int,
    kv_bits: int,
    sm_scale: float,
    n_w: int,
    c: int,
    g: int,
    has_window: bool,
):
    quant = kv_bits < 16
    if quant:
        ks_ref, vs_ref, ck_ref, cv_ref, cks_ref, cvs_ref = rest[:6]
        o_ref, m_ref, l_ref, acc_ref = rest[6:]
    else:
        ck_ref, cv_ref = rest[:2]
        o_ref, m_ref, l_ref, acc_ref = rest[2:]

    b_idx = pl.program_id(0)
    w_idx = pl.program_id(2)
    ctx = ctx_ref[b_idx]

    @pl.when(w_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32).reshape(c * g, -1)  # [C*G, D]
    # chunk index of each flattened query row, and its absolute position
    c_of_r = jax.lax.broadcasted_iota(jnp.int32, (c * g, 1), 0) // g  # [C*G, 1]
    q_pos = ctx + c_of_r

    block_live = w_idx * ps < ctx
    if has_window:
        block_live = block_live & ((w_idx + 1) * ps > win_lo_ref[b_idx])

    def online_update(scores, valid, vf):
        """One online-softmax update: scores/valid [C*G, S], vf [S, D]."""
        scores = jnp.where(valid, scores * sm_scale, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(scores - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, vf, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(block_live)
    def _pool_update():
        k = k_ref[0, 0, :, 0]  # [ps, Dk]
        v = v_ref[0, 0, :, 0]
        if kv_bits == 4:
            k = _unpack_kv4(k)
            v = _unpack_kv4(v)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        if quant:
            kf = kf * ks_ref[0, 0, :, 0].astype(jnp.float32)
            vf = vf * vs_ref[0, 0, :, 0].astype(jnp.float32)
        scores = jax.lax.dot_general(
            q, kf, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [C*G, ps]
        pos = w_idx * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        valid = pos < ctx  # pool tokens all precede the chunk: causal for free
        if has_window:
            valid = valid & (q_pos - pos < win_ref[0])
        online_update(scores, valid, vf)

    @pl.when(w_idx == n_w - 1)
    def _self_chunk():
        # the chunk attends to itself causally (key j visible iff j <= c);
        # padding rows (c >= q_len) mask every key and normalize to zero.
        ck = ck_ref[0, 0]  # [C, Dk]
        cv = cv_ref[0, 0]
        if kv_bits == 4:
            ck = _unpack_kv4(ck)
            cv = _unpack_kv4(cv)
        ckf = ck.astype(jnp.float32)
        cvf = cv.astype(jnp.float32)
        if quant:
            ckf = ckf * cks_ref[0, 0].astype(jnp.float32)
            cvf = cvf * cvs_ref[0, 0].astype(jnp.float32)
        scores = jax.lax.dot_general(
            q, ckf, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [C*G, C]
        j = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)
        valid = (j <= c_of_r) & (j < qlen_ref[b_idx])
        if has_window:
            valid = valid & (c_of_r - j < win_ref[0])
        online_update(scores, valid, cvf)
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / denom).reshape(c, g, -1).astype(o_ref.dtype)


def paged_mqa_prefill_pallas(
    q: jnp.ndarray,  # [B, Hkv, C, G, D]
    k_pool: jnp.ndarray,  # [L, P, ps, Hkv, Dk]  int8 payload or bf16
    v_pool: jnp.ndarray,
    k_scale,  # [L, P, ps, Hkv, 1] f32, or None when kv_bits == 16
    v_scale,
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    ctx_lens: jnp.ndarray,  # [B] int32 — tokens already in the pool
    q_lens: jnp.ndarray,  # [B] int32 — valid chunk tokens per row
    layer: jnp.ndarray,  # [] or [1] int32 — which pool layer to read
    chunk_k: jnp.ndarray,  # [B, Hkv, C, Dk] — this chunk's K/V, not yet pooled
    chunk_v: jnp.ndarray,
    chunk_k_scale,  # [B, Hkv, C, 1] f32, or None
    chunk_v_scale,
    *,
    kv_bits: int,
    sm_scale: float,
    window=None,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hkv, c, g, d = q.shape
    ps = k_pool.shape[2]
    dk = k_pool.shape[-1]
    n_w = tables.shape[1]
    quant = kv_bits < 16
    ctx_lens = ctx_lens.astype(jnp.int32)
    if window is not None:
        win_lo = jnp.maximum(ctx_lens + 1 - jnp.asarray(window, jnp.int32), 0)
    else:
        win_lo = jnp.zeros_like(ctx_lens)

    def page_map(b_, h_, w_, tables_ref, ctx_ref, qlen_ref, win_lo_ref, win_ref, layer_ref):
        n_live = (ctx_ref[b_] + ps - 1) // ps
        first = win_lo_ref[b_] // ps  # 0 when no window
        slot = jnp.clip(jnp.maximum(w_, first), 0, jnp.maximum(n_live - 1, 0))
        return (layer_ref[0], tables_ref[b_, slot], 0, h_, 0)

    def head_map(b_, h_, w_, *_):
        return (b_, h_, 0, 0, 0)

    def chunk_map(b_, h_, w_, *_):
        return (b_, h_, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, c, g, d), head_map),
        pl.BlockSpec((1, 1, ps, 1, dk), page_map),
        pl.BlockSpec((1, 1, ps, 1, dk), page_map),
    ]
    inputs = [q, k_pool, v_pool]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, ps, 1, 1), page_map),
            pl.BlockSpec((1, 1, ps, 1, 1), page_map),
        ]
        inputs += [k_scale, v_scale]
    in_specs += [
        pl.BlockSpec((1, 1, c, dk), chunk_map),
        pl.BlockSpec((1, 1, c, dk), chunk_map),
    ]
    inputs += [chunk_k, chunk_v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, c, 1), chunk_map),
            pl.BlockSpec((1, 1, c, 1), chunk_map),
        ]
        inputs += [chunk_k_scale, chunk_v_scale]

    kernel = functools.partial(
        _prefill_kernel,
        ps=ps,
        kv_bits=kv_bits,
        sm_scale=sm_scale,
        n_w=n_w,
        c=c,
        g=g,
        has_window=window is not None,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(b, hkv, n_w),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, c, g, d), head_map),
        scratch_shapes=[
            pltpu.VMEM((c * g, 1), jnp.float32),
            pltpu.VMEM((c * g, 1), jnp.float32),
            pltpu.VMEM((c * g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, c, g, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
        name=f"paged_mqa_prefill_kv{kv_bits}",
    )(
        tables.astype(jnp.int32),
        ctx_lens,
        q_lens.astype(jnp.int32),
        win_lo,
        jnp.asarray(0 if window is None else window, jnp.int32).reshape(1),
        jnp.asarray(layer, jnp.int32).reshape(1),
        *inputs,
    )


def paged_mqa_prefill_xla(
    q: jnp.ndarray,  # [B, Hkv, C, G, D]
    k_pool: jnp.ndarray,  # [L, P, ps, Hkv, Dk]
    v_pool: jnp.ndarray,
    k_scale,
    v_scale,
    tables: jnp.ndarray,  # [B, W] int32
    ctx_lens: jnp.ndarray,  # [B] int32
    q_lens: jnp.ndarray,  # [B] int32
    layer,  # scalar int32
    chunk_k: jnp.ndarray,  # [B, Hkv, C, Dk]
    chunk_v: jnp.ndarray,
    chunk_k_scale,
    chunk_v_scale,
    *,
    kv_bits: int,
    sm_scale: float,
    window=None,
) -> jnp.ndarray:
    """XLA fallback: lax.scan over page slots (lax.cond skips slots past the
    longest row), then one fused causal self-chunk softmax update."""
    b, hkv, c, g, d = q.shape
    n_layers, n_pages, ps = k_pool.shape[:3]
    n_w = tables.shape[1]
    quant = kv_bits < 16
    layer = jnp.asarray(layer, jnp.int32).reshape(())

    kp = k_pool.reshape(n_layers * n_pages, ps, hkv, -1)
    vp = v_pool.reshape(n_layers * n_pages, ps, hkv, -1)
    if quant:
        ksp = k_scale.reshape(n_layers * n_pages, ps, hkv, 1)
        vsp = v_scale.reshape(n_layers * n_pages, ps, hkv, 1)
    base = layer * n_pages
    ctx_lens = ctx_lens.astype(jnp.int32)
    q_lens = q_lens.astype(jnp.int32)
    qf = q.astype(jnp.float32)
    cpos = jnp.arange(c, dtype=jnp.int32)
    q_pos = ctx_lens[:, None] + cpos[None, :]  # [B, C] absolute query positions
    lo = q_pos + 1 - window if window is not None else None

    def dequant(page, scale):  # [B, S, Hkv, Dk] -> [B, S, Hkv, D] f32
        if kv_bits == 4:
            page = unpack_int4(page, axis=-1)
        page = page.astype(jnp.float32)
        if scale is not None:
            page = page * scale.astype(jnp.float32)
        return page

    def update(carry, kf, vf, valid):
        """kf/vf [B, S, Hkv, D]; valid [B, C, S]."""
        m, l, acc = carry
        scores = jnp.einsum("bhcgd,bshd->bhcgs", qf, kf) * sm_scale
        vmask = valid[:, None, :, None, :]  # [B, 1, C, 1, S]
        scores = jnp.where(vmask, scores, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(vmask, jnp.exp(scores - m_new), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bhcgs,bshd->bhcgd", p, vf)
        return m_new, l_new, acc_new

    def slot_step(carry, w):
        def live(carry):
            pages = base + tables[:, w]  # [B]
            kf = dequant(kp[pages], ksp[pages] if quant else None)
            vf = dequant(vp[pages], vsp[pages] if quant else None)
            pos = w * ps + jnp.arange(ps, dtype=jnp.int32)[None, None, :]  # [1,1,ps]
            valid = pos < ctx_lens[:, None, None]  # [B, 1, ps] -> broadcast C
            valid = jnp.broadcast_to(valid, (b, c, ps))
            if window is not None:
                valid = valid & (pos >= lo[:, :, None])
            return update(carry, kf, vf, valid)

        alive = w * ps < ctx_lens
        if window is not None:
            alive = alive & ((w + 1) * ps > jnp.maximum(ctx_lens + 1 - window, 0))
        return jax.lax.cond(jnp.any(alive), live, lambda cr: cr, carry), None

    m0 = jnp.full((b, hkv, c, g, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, c, g, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, c, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        slot_step, (m0, l0, a0), jnp.arange(n_w, dtype=jnp.int32)
    )

    # fused causal self-chunk term (keys are the chunk's own not-yet-pooled
    # K/V at positions ctx + j)
    ckf = dequant(
        chunk_k.transpose(0, 2, 1, 3),  # [B, C, Hkv, Dk]
        chunk_k_scale.transpose(0, 2, 1, 3) if quant else None,
    )
    cvf = dequant(
        chunk_v.transpose(0, 2, 1, 3),
        chunk_v_scale.transpose(0, 2, 1, 3) if quant else None,
    )
    j = cpos[None, None, :]  # [1, 1, C] key chunk index
    valid = (j <= cpos[None, :, None]) & (j < q_lens[:, None, None])  # [B, C, C]
    if window is not None:
        valid = valid & (cpos[None, :, None] - j < window)
    m, l, acc = update((m, l, acc), ckf, cvf, valid)
    out = acc / jnp.maximum(l, 1e-20)
    return out.astype(q.dtype)
