"""Paged flash-decode GQA attention: index the KV page pool in place.

The serving engine stores every request's KV cache as fixed-size pages in one
shared pool (``serve/kv_cache.py``); a request's *page table* lists its pages
in order.  The old decode path gathered the pool into a contiguous
``[L, B, S, Hkv, D]`` view every token — an O(layers x batch x max_seq) HBM
copy that dwarfed the attention math.  This kernel reads the pool through the
page table instead, PagedAttention-style:

* grid ``(batch, kv_head, page_slot)`` with the slot dimension innermost and
  sequential; the block-spec index map translates ``(row, slot) -> page_id``
  via a scalar-prefetched table, so each grid step DMAs exactly one page.
* slots at or beyond a row's occupied length are *clamped* to the row's last
  live page: consecutive grid steps then ask for the same block and Pallas
  elides the re-fetch — dead slots cost neither DMA nor (via ``pl.when``)
  compute.  Per-token attention traffic is proportional to the row's actual
  cache length, not the table capacity.
* int8/int4 payloads are dequantized in-register with per-(token, head)
  scales, exactly like ``kernels/mqa_decode.py``; bf16 pools skip the scales.
* the *new* token's K/V (computed this step, not yet in the pool) enters the
  online softmax as one extra term in the final grid step, so the caller
  never round-trips it through a gathered view — it scatters the quantized
  payload straight into its page afterwards (``pool.at[:, page, off].set``).

``paged_mqa_decode_xla`` is the XLA fallback for CPU/interpret runs: a
``lax.scan`` over page slots that gathers one page per live slot through the
table (``lax.cond`` skips slots beyond the longest row), with the same online
softmax and fused new-token term.  Oracle: ``kernels/ref.py::
paged_mqa_decode_ref``;  dispatch: ``kernels/ops.py::paged_mqa_decode``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mqa_decode import _unpack_kv4
from repro.quant.pack import unpack_int4

__all__ = ["paged_mqa_decode_pallas", "paged_mqa_decode_xla"]

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG_INF = -1e30


def _paged_kernel(
    # scalar prefetch
    tables_ref,  # [B, W] int32
    lengths_ref,  # [B] int32
    win_lo_ref,  # [B] int32 — first in-window position (0 when no window)
    layer_ref,  # [1] int32
    # blocks
    q_ref,  # [1, 1, G, D]
    k_ref,  # [1, 1, ps, 1, Dk]   (one page of one kv head)
    v_ref,
    *rest,  # [ks_ref, vs_ref,] nk_ref, nv_ref, [nks_ref, nvs_ref,] o_ref + scratch
    ps: int,
    kv_bits: int,
    sm_scale: float,
    n_w: int,
    has_window: bool,
):
    quant = kv_bits < 16
    if quant:
        ks_ref, vs_ref, nk_ref, nv_ref, nks_ref, nvs_ref = rest[:6]
        o_ref, m_ref, l_ref, acc_ref = rest[6:]
    else:
        nk_ref, nv_ref = rest[:2]
        o_ref, m_ref, l_ref, acc_ref = rest[2:]

    b_idx = pl.program_id(0)
    w_idx = pl.program_id(2)
    length = lengths_ref[b_idx]  # cache tokens; new token sits at `length`

    @pl.when(w_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, D]

    # window lower bound over the total (cache + new token) length
    lo = win_lo_ref[b_idx]
    block_live = w_idx * ps < length
    if has_window:
        block_live = block_live & ((w_idx + 1) * ps > lo)

    @pl.when(block_live)
    def _update():
        k = k_ref[0, 0, :, 0]  # [ps, Dk]
        v = v_ref[0, 0, :, 0]
        if kv_bits == 4:
            k = _unpack_kv4(k)
            v = _unpack_kv4(v)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        if quant:
            kf = kf * ks_ref[0, 0, :, 0].astype(jnp.float32)
            vf = vf * vs_ref[0, 0, :, 0].astype(jnp.float32)
        scores = jax.lax.dot_general(
            q, kf, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [G, ps]
        scores = scores * sm_scale
        pos = w_idx * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        valid = pos < length
        if has_window:
            valid = valid & (pos >= lo)
        scores = jnp.where(valid, scores, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        p = jnp.where(valid, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, vf, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(w_idx == n_w - 1)
    def _finish():
        # fused new-token term: the token produced this step attends to itself
        # (always inside any window — distance 0) without touching the pool.
        nk = nk_ref[0]  # [1, Dk]
        nv = nv_ref[0]
        if kv_bits == 4:
            nk = _unpack_kv4(nk)
            nv = _unpack_kv4(nv)
        nkf = nk.astype(jnp.float32)
        nvf = nv.astype(jnp.float32)
        if quant:
            nkf = nkf * nks_ref[0, 0, 0].astype(jnp.float32)
            nvf = nvf * nvs_ref[0, 0, 0].astype(jnp.float32)
        s_new = jax.lax.dot_general(
            q, nkf, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [G, 1]
        s_new = s_new * sm_scale
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s_new)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s_new - m_new)  # [G, 1]
        denom = l_ref[...] * alpha + p
        acc = acc_ref[...] * alpha + p * nvf
        o_ref[0, 0] = (acc / jnp.maximum(denom, 1e-20)).astype(o_ref.dtype)


def paged_mqa_decode_pallas(
    q: jnp.ndarray,  # [B, Hkv, G, D]
    k_pool: jnp.ndarray,  # [L, P, ps, Hkv, Dk]  int8 payload or bf16
    v_pool: jnp.ndarray,
    k_scale,  # [L, P, ps, Hkv, 1] f32, or None when kv_bits == 16
    v_scale,
    tables: jnp.ndarray,  # [B, W] int32 page tables (zero-padded)
    lengths: jnp.ndarray,  # [B] int32 — tokens already in the cache
    layer: jnp.ndarray,  # [] or [1] int32 — which pool layer to read
    new_k: jnp.ndarray,  # [B, Hkv, Dk] — this step's K/V, not yet in the pool
    new_v: jnp.ndarray,
    new_k_scale,  # [B, Hkv, 1] f32, or None
    new_v_scale,
    *,
    kv_bits: int,
    sm_scale: float,
    window: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hkv, g, d = q.shape
    n_pages, ps = k_pool.shape[1], k_pool.shape[2]
    dk = k_pool.shape[-1]
    n_w = tables.shape[1]
    quant = kv_bits < 16
    lengths = lengths.astype(jnp.int32)
    # per-row first in-window position over the total (cache + new) length;
    # window may be a traced scalar (per-layer windows come out of lax.scan)
    if window is not None:
        win_lo = jnp.maximum(lengths + 1 - jnp.asarray(window, jnp.int32), 0)
    else:
        win_lo = jnp.zeros_like(lengths)

    def page_map(b_, h_, w_, tables_ref, lengths_ref, win_lo_ref, layer_ref):
        # Clamp dead slots to the row's nearest live page — below the window
        # start as well as past the length: consecutive grid steps then index
        # the same block and Pallas skips the re-fetch, so windowed layers
        # DMA ~window/ps pages per token, not the whole cache.
        n_live = (lengths_ref[b_] + ps - 1) // ps
        first = win_lo_ref[b_] // ps  # 0 when no window
        slot = jnp.clip(jnp.maximum(w_, first), 0, jnp.maximum(n_live - 1, 0))
        return (layer_ref[0], tables_ref[b_, slot], 0, h_, 0)

    def head_map(b_, h_, w_, *_):
        return (b_, h_, 0, 0)

    def tok_map(b_, h_, w_, *_):
        return (b_, h_, 0)

    in_specs = [
        pl.BlockSpec((1, 1, g, d), head_map),
        pl.BlockSpec((1, 1, ps, 1, dk), page_map),
        pl.BlockSpec((1, 1, ps, 1, dk), page_map),
    ]
    inputs = [q, k_pool, v_pool]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, ps, 1, 1), page_map),
            pl.BlockSpec((1, 1, ps, 1, 1), page_map),
        ]
        inputs += [k_scale, v_scale]
    in_specs += [
        pl.BlockSpec((1, 1, dk), tok_map),
        pl.BlockSpec((1, 1, dk), tok_map),
    ]
    inputs += [new_k, new_v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, 1), tok_map),
            pl.BlockSpec((1, 1, 1), tok_map),
        ]
        inputs += [new_k_scale, new_v_scale]

    kernel = functools.partial(
        _paged_kernel,
        ps=ps,
        kv_bits=kv_bits,
        sm_scale=sm_scale,
        n_w=n_w,
        has_window=window is not None,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b, hkv, n_w),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d), head_map),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
        name=f"paged_mqa_decode_kv{kv_bits}",
    )(
        tables.astype(jnp.int32),
        lengths,
        win_lo,
        jnp.asarray(layer, jnp.int32).reshape(1),
        *inputs,
    )


def paged_mqa_decode_xla(
    q: jnp.ndarray,  # [B, Hkv, G, D]
    k_pool: jnp.ndarray,  # [L, P, ps, Hkv, Dk]
    v_pool: jnp.ndarray,
    k_scale,
    v_scale,
    tables: jnp.ndarray,  # [B, W] int32
    lengths: jnp.ndarray,  # [B] int32
    layer,  # scalar int32
    new_k: jnp.ndarray,  # [B, Hkv, Dk]
    new_v: jnp.ndarray,
    new_k_scale,
    new_v_scale,
    *,
    kv_bits: int,
    sm_scale: float,
    window: int | None = None,
) -> jnp.ndarray:
    """XLA fallback: lax.scan over page slots, one [B]-page gather per live
    slot through the table.  ``lax.cond`` skips slots past the longest row,
    so CPU walltime scales with occupied length, not table capacity (the
    kernel's per-row clamping, batch-coarsened)."""
    b, hkv, g, d = q.shape
    n_layers, n_pages, ps = k_pool.shape[:3]
    n_w = tables.shape[1]
    quant = kv_bits < 16
    layer = jnp.asarray(layer, jnp.int32).reshape(())

    # fold the layer index into the page axis so per-slot gathers never
    # materialize a whole layer's pool slice
    kp = k_pool.reshape(n_layers * n_pages, ps, hkv, -1)
    vp = v_pool.reshape(n_layers * n_pages, ps, hkv, -1)
    if quant:
        ksp = k_scale.reshape(n_layers * n_pages, ps, hkv, 1)
        vsp = v_scale.reshape(n_layers * n_pages, ps, hkv, 1)
    base = layer * n_pages
    lengths = lengths.astype(jnp.int32)
    qf = q.astype(jnp.float32)
    lo = lengths + 1 - window if window is not None else None

    def dequant(page, scale):  # [B, ps, Hkv, Dk] -> [B, ps, Hkv, D] f32
        if kv_bits == 4:
            page = unpack_int4(page, axis=-1)
        page = page.astype(jnp.float32)
        if scale is not None:
            page = page * scale.astype(jnp.float32)
        return page

    def slot_step(carry, w):
        def update(carry):
            m, l, acc = carry
            pages = base + tables[:, w]  # [B]
            kf = dequant(kp[pages], ksp[pages] if quant else None)
            vf = dequant(vp[pages], vsp[pages] if quant else None)
            scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf) * sm_scale
            pos = w * ps + jnp.arange(ps, dtype=jnp.int32)[None, :]  # [1, ps]
            valid = pos < lengths[:, None]
            if window is not None:
                valid = valid & (pos >= lo[:, None])
            vmask = valid[:, None, None, :]
            scores = jnp.where(vmask, scores, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(scores, axis=-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.where(vmask, jnp.exp(scores - m_new), 0.0)
            l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bhgs,bshd->bhgd", p, vf)
            return m_new, l_new, acc_new

        # a slot is live if ANY row has cached tokens in it that fall inside
        # its window — per-row, so short or pow2-padding rows (lengths == 0)
        # can't pin the whole batch's scan open
        alive = w * ps < lengths
        if window is not None:
            alive = alive & ((w + 1) * ps > jnp.maximum(lo, 0))
        carry = jax.lax.cond(jnp.any(alive), update, lambda c: c, carry)
        return carry, None

    m0 = jnp.full((b, hkv, g, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        slot_step, (m0, l0, a0), jnp.arange(n_w, dtype=jnp.int32)
    )

    # fused new-token term (always valid, never read from the pool)
    nkf = dequant(new_k[:, None], new_k_scale[:, None] if quant else None)
    nvf = dequant(new_v[:, None], new_v_scale[:, None] if quant else None)
    s_new = jnp.einsum("bhgd,bshd->bhgs", qf, nkf) * sm_scale  # [B,Hkv,G,1]
    m_new = jnp.maximum(m, s_new)
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s_new - m_new)
    denom = l * alpha + p
    acc = acc * alpha + jnp.einsum("bhgs,bshd->bhgd", p, nvf)
    return (acc / jnp.maximum(denom, 1e-20)).astype(q.dtype)
