"""Deterministic synthetic token pipeline.

Properties that matter at scale (and are tested):

  * **Deterministic & replayable**: batch contents are a pure function of
    (seed, step) — a restarted/elastically-rescaled job regenerates exactly
    the batches it would have seen, so checkpoint/restart is exact.
  * **Host-shardable**: each host materializes only its slice
    (``host_slice``); slices concatenate to the global batch regardless of
    host count — resharding to a different host topology replays identically.
  * **Prefetchable**: ``iterate`` runs a one-batch-ahead double buffer on a
    background thread, overlapping host data generation with device steps.

Token statistics: a mixture of Zipfian unigrams and a shift-register
"grammar" so the LM loss has learnable structure (used by the train-smoke
tests, which assert the loss actually falls).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _tokens_for(cfg: DataConfig, step: int, lo: int, hi: int) -> np.ndarray:
    """Rows [lo, hi) of the global batch at `step`.  Each ROW is seeded
    independently by (seed, step, row), so any host-slice decomposition of
    the global batch yields identical data — the elastic-rescale invariant."""
    rows = []
    for r in range(lo, hi):
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, r]))
        ranks = rng.zipf(1.3, size=cfg.seq_len + 1).astype(np.int64)
        rows.append((ranks - 1) % cfg.vocab)
    toks = np.stack(rows)
    # inject learnable bigram structure: every third token repeats prev+1
    mask = (np.arange(cfg.seq_len + 1) % 3) == 2
    toks[:, mask[: toks.shape[1]]] = (np.roll(toks, 1, axis=1) + 1)[:, mask] % cfg.vocab
    return toks.astype(np.int32)


def make_batch(
    cfg: DataConfig,
    step: int,
    arch: Optional[ArchConfig] = None,
    host_slice: tuple[int, int] | None = None,
) -> dict:
    lo, hi = host_slice or (0, cfg.global_batch)
    toks = _tokens_for(cfg, step, lo, hi)
    batch = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }
    if arch is not None and arch.prefix_len:
        from repro.models.frontends import prefix_embeddings

        batch["prefix_emb"] = prefix_embeddings(arch, hi - lo, seed=cfg.seed + step)
    return batch


def batch_specs(cfg: DataConfig, arch: Optional[ArchConfig] = None) -> dict:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len), jnp.int32),
    }
    if arch is not None and arch.prefix_len:
        from repro.models.frontends import prefix_spec

        specs["prefix_emb"] = prefix_spec(arch, cfg.global_batch)
    return specs


def iterate(
    cfg: DataConfig,
    start_step: int = 0,
    arch: Optional[ArchConfig] = None,
    host_slice: tuple[int, int] | None = None,
    prefetch: int = 2,
) -> Iterator[dict]:
    """Background-thread prefetching iterator (double buffering)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            q.put(make_batch(cfg, step, arch, host_slice))
            step += 1

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
