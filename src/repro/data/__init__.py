from repro.data.pipeline import DataConfig, make_batch, batch_specs

__all__ = ["DataConfig", "make_batch", "batch_specs"]
