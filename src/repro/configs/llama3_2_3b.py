"""Llama-3.2-3B — small llama3 [hf:meta-llama/Llama-3.2-3B].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.  Full attention ->
long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    head_dim=128,
    rope_theta=500000.0,
    serve_w_bits=8,
    serve_kv_bits=8,
)
