"""The assigned input-shape set (same four shapes for every LM arch) and the
(arch x shape) cell enumeration with applicability rules (DESIGN.md SS6)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

Kind = Literal["train", "prefill", "decode"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Kind


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cells_for_arch(cfg) -> list[str]:
    """Which of the four shapes run for this arch.  long_500k requires
    sub-quadratic attention (SSM/hybrid/SWA); pure full-attention archs skip
    it (noted in DESIGN.md SS6).  No encoder-only archs are assigned, so all
    archs run decode shapes."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        cells.append("long_500k")
    return cells
