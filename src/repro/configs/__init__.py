"""Architecture configs (one module per assigned arch) + input-shape registry."""
from repro.configs.base import ArchConfig, get_config, list_archs
from repro.configs.shapes import SHAPES, ShapeSpec, cells_for_arch

__all__ = ["ArchConfig", "get_config", "list_archs", "SHAPES", "ShapeSpec", "cells_for_arch"]
