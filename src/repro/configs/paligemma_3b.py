"""PaliGemma-3B — SigLIP + gemma backbone [arXiv:2407.07726; hf].

18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384 vocab=257216.  The SigLIP
vision frontend is a STUB per the assignment: input_specs() supplies 256
precomputed patch embeddings as a prefix.  Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    prefix_len=256,
    serve_w_bits=8,
    serve_kv_bits=8,
)
