"""Mixtral 8x22B — 8 experts top-2, SWA [arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.  Sliding-window
attention (window 4096 per the assignment note) -> long_500k runs.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    head_dim=128,
    n_experts=8,
    top_k=2,
    window=4096,
    subquadratic=True,
    serve_w_bits=8,
    serve_kv_bits=8,
    rope_theta=1000000.0,
)
