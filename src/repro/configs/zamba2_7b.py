"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 Mamba2 layers, d_model=3584, ssm_state=64; a SHARED full-attention block
(32H, kv=32) + MLP (d_ff=14336) applied every 6 SSM blocks (weights shared
across applications).  vocab=32000.  Sub-quadratic -> long_500k runs.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    attn_every=6,
    subquadratic=True,
    serve_w_bits=8,
    serve_kv_bits=8,
)
