"""Kimi K2 — trillion-parameter MoE (paper-table config) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert) vocab=163840, MoE 384
experts top-8.  First layer dense (DeepSeek-V3-style).  Training dry-runs use
Adafactor (Adam m/v for 1e12 params exceeds a 256-chip pod's HBM) and w4
serving weights (1T params must be <=4-bit to serve inside one pod).
Pure full attention -> long_500k skipped (DESIGN.md SS6).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,
    n_experts=384,
    top_k=8,
    first_dense=1,
    serve_w_bits=4,
    serve_kv_bits=8,
    optimizer="adafactor",
    remat="full",
    rope_theta=50000.0,
)
