"""Mamba2-130M — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768, attention-free, ssm_state=128, vocab=50280.  Attention-free
-> long_500k runs (O(1)-state decode).  The paper's attention-specific pieces
(mqa_decode kernel) are N/A; the multi-precision matmul path applies to the
in/out projections (DESIGN.md SS6).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,       # unused by SSM math; kept for API uniformity
    n_kv_heads=12,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    subquadratic=True,
    serve_w_bits=8,
    serve_kv_bits=8,
)
