"""Minitron-4B — pruned Nemotron [arXiv:2407.14679; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.  Full attention ->
long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
    head_dim=128,
    serve_w_bits=8,
    serve_kv_bits=8,
)
