"""MusicGen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048.  The EnCodec/T5
conditioning frontend is a STUB per the assignment: input_specs() provides
precomputed conditioning frame embeddings as a prefix.  Full attention ->
long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    head_dim=64,
    prefix_len=64,
    serve_w_bits=8,
    serve_kv_bits=8,
)
