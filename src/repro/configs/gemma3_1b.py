"""Gemma3-1B — 5:1 local:global attention, 128k ctx [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144; local layers use a
1024-token sliding window, every 6th layer is global.  SWA majority ->
long_500k runs (decode is O(S) on the global layers, O(w) on local).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    head_dim=256,
    window=1024,
    local_ratio=5,
    subquadratic=True,
    serve_w_bits=4,
    serve_kv_bits=8,
    rope_theta=1000000.0,
)
