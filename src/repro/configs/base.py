"""ArchConfig: one dataclass describing every supported architecture, plus the
registry the launcher/tests/benchmarks resolve ``--arch <id>`` against.

Each assigned architecture gets its own module in this package with the exact
public-literature config; ``reduced()`` derives the CPU-smoke-test variant
(same family/topology, tiny dims).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, replace
from typing import Literal, Optional

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    first_dense: int = 0  # leading dense layers before MoE stack
    moe_dispatch: str = "replicated"  # or "alltoall"
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    attn_every: int = 0  # hybrid: shared attention after every N ssm blocks
    ssm_head_p: int = 64

    # attention pattern
    window: Optional[int] = None  # sliding-window size
    local_ratio: int = 0  # gemma3-style: local_ratio local layers per 1 global

    # modality frontend stub (vlm/audio): prefix embeddings length
    prefix_len: int = 0

    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # serving-side multi-precision (the paper's technique)
    serve_w_bits: int = 8
    serve_kv_bits: int = 8

    # training
    optimizer: str = "adamw"  # kimi uses adafactor (1T params)
    remat: str = "full"  # "none" | "dots" | "full"

    # long_500k applicability (sub-quadratic attention available?)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding tables padded to 256 so the vocab dim shards on any
        reasonable model-parallel degree (pad logits are masked in the loss
        and at sampling)."""
        return -(-self.vocab // 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        h, kv, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        mlp = 3 * d * f
        if self.family == "ssm":
            from repro.models.ssm import ssm_dims

            dims = ssm_dims(d, self.ssm_state, self.ssm_head_p)
            blk = d * (2 * dims.d_inner + 2 * dims.state + dims.n_heads) + dims.d_inner * d
            return self.n_layers * blk + 2 * v * d
        if self.family == "hybrid":
            from repro.models.ssm import ssm_dims

            dims = ssm_dims(d, self.ssm_state, self.ssm_head_p)
            blk = d * (2 * dims.d_inner + 2 * dims.state + dims.n_heads) + dims.d_inner * d
            shared = attn + 3 * d * f
            return self.n_layers * blk + shared + 2 * v * d
        if self.n_experts:
            moe = 3 * d * f * self.n_experts + d * self.n_experts
            dense_l = attn + mlp
            moe_l = attn + moe
            return (
                self.first_dense * dense_l
                + (self.n_layers - self.first_dense) * moe_l
                + 2 * v * d
            )
        return self.n_layers * (attn + mlp) + 2 * v * d

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        h, kv, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        act_moe = 3 * d * f * self.top_k + d * self.n_experts
        dense_l = attn + 3 * d * f
        moe_l = attn + act_moe
        return (
            self.first_dense * dense_l
            + (self.n_layers - self.first_dense) * moe_l
            + 2 * self.vocab * d
        )

    def reduced(self) -> "ArchConfig":
        """CPU smoke-test variant: same family & topology, tiny dims."""
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4 if not self.attn_every else self.attn_every + 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            first_dense=min(self.first_dense, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_p=32,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            window=min(self.window, 64) if self.window else None,
            prefix_len=min(self.prefix_len, 8) if self.prefix_len else 0,
            remat="none",
        )


_REGISTRY: dict[str, str] = {
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "minitron-4b": "repro.configs.minitron_4b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "yi-9b": "repro.configs.yi_9b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "mamba2-130m": "repro.configs.mamba2_130m",
}


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(_REGISTRY[name])
    return mod.CONFIG


def list_archs() -> list[str]:
    return sorted(_REGISTRY)
