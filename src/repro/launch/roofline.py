"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

For each (arch x shape) cell on the single-pod mesh, derives the three
roofline terms from the compiled per-device module:

    compute   = flops_per_device / peak_flops_per_chip
    memory    = bytes_per_device / hbm_bw_per_chip
    collective= wire_bytes_per_device / ici_bw_per_chip

(dividing per-device quantities by per-chip rates == the assignment's
global/(chips x rate) formulas), plus MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

Hardware constants (TPU v5e class, per the assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

    PYTHONPATH=src python -m repro.launch.roofline [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link (wire-bytes modelled per chip through its links)

# Ring-style wire weighting per collective type (bytes crossing a chip's
# links per byte of output-operand, n = participants; n is large so the
# (n-1)/n factors ~1; all-reduce costs ~2x (reduce-scatter + all-gather)).
WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def model_flops(arch_name: str, shape_name: str) -> float:
    cfg = get_config(arch_name)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    tokens = shape.global_batch
    return 2.0 * n_active * tokens


def analyze(rec: dict) -> dict:
    flops = rec.get("flops_per_device", 0.0)
    coll = rec.get("collective_bytes", {})
    wire = sum(WIRE_FACTOR.get(k, 1.0) * v for k, v in coll.items())
    n_dev = 1
    for d in rec.get("mesh", []):
        n_dev *= d
    # HBM bytes: XLA's post-fusion `bytes accessed` counts while bodies once;
    # scale it by the same loop-multiplicity factor observed on FLOPs
    # (corrected/uncorrected).  The raw unfused-HLO byte sum is kept as an
    # upper bound.
    xla_flops = rec.get("xla_flops_per_device", 0.0)
    xla_bytes = rec.get("xla_bytes_per_device", 0.0)
    mult = flops / xla_flops if xla_flops > 0 else 1.0
    mult = max(mult, 1.0)
    byts = xla_bytes * mult
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = wire / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = flops * n_dev
    step_time = max(terms.values())
    useful_frac = mf / hlo_global if hlo_global else 0.0
    # roofline fraction: useful model FLOP/s achieved at the bound, vs peak
    mfu_bound = (mf / n_dev / PEAK_FLOPS) / step_time if step_time else 0.0
    return {
        **rec,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flop_ratio": useful_frac,
        "roofline_fraction": mfu_bound,
        "wire_bytes": wire,
        "hbm_bytes_scaled": byts,
        "hbm_bytes_unfused_ub": rec.get("bytes_per_device", 0.0),
        "loop_mult": mult,
    }


def load_all(mesh_tag: str = "pod1") -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh_tag}.json"))):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("ok"):
            out.append(analyze(rec))
        else:
            out.append(rec)
    return out


def table(records: list[dict], markdown: bool = True) -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "HBM GB/dev | useful/HLO | roofline frac |"
    )
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in records:
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | FAILED: {r.get('error','')[:60]} |||||||"
            )
            continue
        hbm_gb = (r.get("argument_size_in_bytes") or 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {hbm_gb:.2f} | {r['useful_flop_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    recs = load_all(args.mesh)
    if args.json:
        print(json.dumps(recs, indent=1))
        return
    print(table(recs))
    ok = [r for r in recs if r.get("ok")]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        coll_bound = max(ok, key=lambda r: r["t_collective_s"] / max(r["t_compute_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
              f"({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound: {coll_bound['arch']} x {coll_bound['shape']}")


if __name__ == "__main__":
    main()
