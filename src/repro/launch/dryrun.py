import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analyses for §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Outputs one JSON per cell under experiments/dryrun/ (cached; --force to
redo).  The compile itself is the test: sharding mismatches, non-divisible
dimensions, or unsupported collectives fail here, not on the pod.

(no ``from __future__ import annotations`` here: the XLA_FLAGS lines must
stay the first statements in the file.)
"""
import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, cells_for_arch, get_config, list_archs
from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import DataConfig, batch_specs
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as model_lib
from repro.train.trainer import TrainConfig, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


# ------------------------------------------------------------- input specs --
def input_specs(arch: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    if shape.kind == "train":
        dc = DataConfig(arch.vocab, shape.seq_len, shape.global_batch)
        return batch_specs(dc, arch)
    if shape.kind == "prefill":
        dc = DataConfig(arch.vocab, shape.seq_len - arch.prefix_len, shape.global_batch)
        specs = batch_specs(dc, arch)
        specs.pop("labels")
        return specs
    # decode: one new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}


def _div(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def _axis_size(mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    out = 1
    for n in names:
        out *= mesh.shape[n]
    return out


def batch_sharding_spec(mesh, batch: int, data_only: bool = False):
    if data_only:
        ba = tuple(mesh.axis_names)
        if _div(batch, _axis_size(mesh, ba)):
            return ba
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if ba and _div(batch, _axis_size(mesh, ba)):
        return ba
    # try data alone
    if "data" in mesh.axis_names and _div(batch, mesh.shape["data"]):
        return ("data",)
    return None


def cache_specs(arch: ArchConfig, shape: ShapeSpec, mesh) -> tuple[dict, dict]:
    """(ShapeDtypeStruct tree, PartitionSpec tree) for the decode cache."""
    cache = jax.eval_shape(
        partial(model_lib.init_cache, arch, shape.global_batch, shape.seq_len)
    )
    ba = batch_sharding_spec(mesh, shape.global_batch)
    model_ax = "model" if "model" in mesh.axis_names else None
    specs = {}
    for name, leaf in cache.items():
        if name == "pos":
            specs[name] = P()
        elif name in ("k", "v", "k_scale", "v_scale"):
            # [L(or groups), B, S, kv, hd(or 1)]
            s_dim = leaf.shape[2]
            if ba is not None:
                seq_ax = model_ax if _div(s_dim, _axis_size(mesh, model_ax)) else None
                specs[name] = P(None, ba, seq_ax, None, None)
            else:  # long-context batch=1: shard the sequence over everything
                all_ax = tuple(a for a in mesh.axis_names)
                seq_ax = all_ax if _div(s_dim, _axis_size(mesh, all_ax)) else (
                    model_ax if _div(s_dim, _axis_size(mesh, model_ax)) else None
                )
                specs[name] = P(None, None, seq_ax, None, None)
        elif name.startswith("ssm"):
            # [L, B, H, P, N]
            h = leaf.shape[2]
            h_ax = model_ax if _div(h, _axis_size(mesh, model_ax)) else None
            specs[name] = P(None, ba, h_ax, None, None)
        else:
            specs[name] = P(*([None] * leaf.ndim))
    return cache, specs


def _spec_tree_to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# -------------------------------------------------------- collective bytes --
_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"\b([a-z]+\d+|pred)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def collective_bytes_from_hlo(hlo: str) -> dict[str, float]:
    """Sums output-operand bytes of every collective op in the optimized
    (post-SPMD, per-device) HLO.  Wire-cost weighting per op type uses the
    standard ring formulas; shapes are per-device."""
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        ls = line.lstrip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s*((?:[\w\-]+)\()", ls)
        if not m:
            continue
        op = m.group(2)[:-1]
        name = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                name = c
                break
        if name is None:
            continue
        if op.endswith("-done"):
            continue  # avoid double counting start/done pairs
        ty = m.group(1)
        bytes_ = 0.0
        for dt, dims in _SHAPE_RE.findall(ty):
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            bytes_ += n * _DTYPE_BYTES.get(dt, 4)
        out[name] += bytes_
    return out


# -------------------------------------------------------------- lowering ----
def use_fsdp_mapping(arch: ArchConfig, shape: ShapeSpec, mesh) -> bool:
    """FSDP/ZeRO-3 mapping for non-MoE train/prefill: tokens >> devices and
    params small enough that per-layer weight gathers beat per-layer
    activation all-reduces (EXPERIMENTS.md §Perf hillclimb #1)."""
    if arch.n_experts or shape.kind == "decode":
        return False
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    return shape.global_batch % n_dev == 0 and arch.param_count() < 2e10


def build_lowered(arch: ArchConfig, shape: ShapeSpec, mesh):
    sh.set_mesh(mesh, data_only=use_fsdp_mapping(arch, shape, mesh))
    specs = input_specs(arch, shape)
    params_shape = jax.eval_shape(
        partial(model_lib.init_params, arch), jax.random.PRNGKey(0)
    )
    batch_axes = batch_sharding_spec(
        mesh, shape.global_batch, data_only=use_fsdp_mapping(arch, shape, mesh)
    )
    tok_spec = P(batch_axes, None)
    batch_sharding = {
        k: NamedSharding(mesh, tok_spec if v.ndim == 2 else P(batch_axes, None, None))
        for k, v in specs.items()
    }

    if shape.kind == "train":
        tc = TrainConfig(microbatches=1)
        step_fn, opt_init = make_train_step(arch, tc, mesh)
        opt_shape = jax.eval_shape(opt_init, params_shape)
        p_sh = sh.tree_shardings(params_shape, mesh)
        o_sh = sh.tree_shardings(opt_shape, mesh)
        fn = jax.jit(
            step_fn,
            in_shardings=(p_sh, o_sh, batch_sharding, NamedSharding(mesh, P())),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = fn.lower(
                params_shape, opt_shape, specs, jax.ShapeDtypeStruct((), jnp.int32)
            )
        return lowered

    # serving paths run on quantized weights (the paper's technique)
    qparams_shape = jax.eval_shape(
        lambda: model_lib.quantize_params(
            model_lib.init_params(arch, jax.random.PRNGKey(0)), arch.serve_w_bits
        )
    )
    qp_sh = sh.tree_shardings(qparams_shape, mesh)

    if shape.kind == "prefill":
        fn = jax.jit(
            lambda p, b: model_lib.prefill(p, b, arch, shape.seq_len, mesh),
            in_shardings=(qp_sh, batch_sharding),
        )
        with mesh:
            lowered = fn.lower(qparams_shape, specs)
        return lowered

    # decode
    cache_shape, cache_spec = cache_specs(arch, shape, mesh)
    c_sh = _spec_tree_to_shardings(mesh, cache_spec)
    tok_sharding = NamedSharding(mesh, tok_spec)
    fn = jax.jit(
        lambda p, t, c: model_lib.decode_step(p, t, c, arch, mesh),
        in_shardings=(qp_sh, tok_sharding, c_sh),
        donate_argnums=(2,),
    )
    with mesh:
        lowered = fn.lower(qparams_shape, specs["tokens"], cache_shape)
    return lowered


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, force: bool = False) -> dict:
    mesh_tag = "pod2" if multi_pod else "pod1"
    os.makedirs(OUT_DIR, exist_ok=True)
    out_path = os.path.join(OUT_DIR, f"{arch_name}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            cached = json.load(f)
        if cached.get("ok"):  # failed cells always re-run
            return cached
    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": list(mesh.shape.values()),
        "axes": list(mesh.axis_names),
        "kind": shape.kind,
    }
    t0 = time.time()
    try:
        lowered = build_lowered(arch, shape, mesh)
        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1
        mem = compiled.memory_analysis()
        print(mem)
        if mem is not None:
            for attr in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            ):
                rec[attr] = getattr(mem, attr, None)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0] if cost else {}
        rec["xla_flops_per_device"] = float(cost.get("flops", 0.0)) if cost else 0.0
        rec["xla_bytes_per_device"] = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
        hlo = compiled.as_text()
        # multiplicity-corrected analysis (XLA counts while bodies ONCE; our
        # layer stacks are scans — see launch/hlo_analysis.py)
        from repro.launch.hlo_analysis import analyze_hlo

        corrected = analyze_hlo(hlo)
        rec["flops_per_device"] = corrected["flops"]
        rec["bytes_per_device"] = corrected["hbm_bytes"]
        rec["collective_bytes"] = corrected["collective_bytes"]
        rec["while_loops"] = corrected["while_loops"]
        rec["collective_bytes_toplevel"] = collective_bytes_from_hlo(hlo)
        rec["hlo_lines"] = hlo.count("\n")
        print({"flops": rec["flops_per_device"], "hbm": rec["bytes_per_device"]})
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, don't mask others
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        sh.set_mesh(None)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK" if rec.get("ok") else f"FAIL: {rec.get('error', '')[:200]}"
    print(f"[dryrun] {arch_name} x {shape_name} x {mesh_tag}: {status} "
          f"(lower {rec.get('lower_s', 0):.0f}s compile {rec.get('compile_s', 0):.0f}s)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        cells = [
            (a, s)
            for a in list_archs()
            for s in cells_for_arch(get_config(a))
        ]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]
    failures = 0
    for a, s in cells:
        for mp in meshes:
            rec = run_cell(a, s, mp, force=args.force)
            failures += 0 if rec.get("ok") else 1
    print(f"[dryrun] done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
