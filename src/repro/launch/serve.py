"""Serving launcher: multi-precision quantized inference (the paper's use
case) with the batched request engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
        --requests 8 --new-tokens 16 [--w-bits 4]
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--w-bits", type=int, default=0, help="0 = arch default")
    ap.add_argument("--no-quantize", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import transformer as model_lib
    from repro.train.server import Request, Server

    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()
    if args.w_bits:
        arch = dataclasses.replace(arch, serve_w_bits=args.w_bits)

    params = model_lib.init_params(arch, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens + arch.prefix_len + 8
    srv = Server(
        arch, params, batch_size=args.batch_size, max_len=max_len,
        quantize=not args.no_quantize,
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, arch.vocab, args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for i in range(args.requests)
    ]
    srv.serve(reqs)
    stats = srv.stats
    print(json.dumps({
        "arch": arch.name,
        "w_bits": arch.serve_w_bits,
        "kv_bits": arch.serve_kv_bits,
        "requests": len(reqs),
        "tokens_out": stats.tokens_out,
        "prefill_s": round(stats.prefill_s, 3),
        "decode_s": round(stats.decode_s, 3),
        "decode_tok_per_s": round(stats.tokens_out / max(stats.decode_s, 1e-9), 1),
        "sample_output": reqs[0].out_tokens[:8],
    }, indent=1))


if __name__ == "__main__":
    main()
