"""Serving launcher: continuous-batching multi-precision quantized inference
(the paper's use case at traffic).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
        --requests 8 --new-tokens 16 --precision-mix 4,8 --shared-prefix 64 \
        --temperature 0.8 --top-p 0.95 --seed 7

``--precision-mix`` assigns weight precisions to requests round-robin, so a
single engine decodes W4A16 and W8A16 requests in the same step (one batched
kernel call per precision group).  ``--w-bits`` forces one precision for all
requests (0 = arch default); ``--no-quantize`` serves raw bf16 weights.
``--shared-prefix N`` gives every request the same N-token system prompt:
the first request prefills it cold, every follower adopts the cached prefix
pages and prefills only its unique tail (see the prefix_* stats in the
output).  ``--prefill-chunk`` bounds per-step prefill work so long prompts
interleave with running decodes; ``--no-prefix-cache`` disables reuse.
``--spec-k K`` turns on self-speculative decoding: every request drafts up
to K tokens per round with the cheap ``--draft-bits`` weight set and
verifies them in one pass at its own precision (exact acceptance for greedy,
rejection sampling for sampled requests; see spec_* stats).

Sampling: ``--temperature`` (0 = greedy argmax, the default), ``--top-k``,
``--top-p`` and ``--seed`` build each request's ``SamplingParams``; request
``i`` uses ``seed + i``, so rerunning with the same seed reproduces every
stream exactly while distinct requests stay decorrelated.  ``--eos-id``
terminates a request the moment it emits that token instead of always
burning the full ``--new-tokens`` budget.

Requests are driven through the streaming ``ServeEngine.generate()`` API —
the JSON report includes per-request ``outputs`` (token prefixes) and
``finish_reasons`` collected from the stream.
"""
from __future__ import annotations

import argparse
import json
from typing import Optional


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4, help="concurrent slots")
    ap.add_argument("--page-size", type=int, default=16, help="KV page tokens")
    ap.add_argument("--w-bits", type=int, default=0, help="0 = arch default")
    ap.add_argument(
        "--precision-mix", default="",
        help="comma-separated w_bits cycled over requests, e.g. '4,8'",
    )
    ap.add_argument("--kv-bits", type=int, default=0, help="0 = arch default")
    ap.add_argument("--no-quantize", action="store_true")
    ap.add_argument(
        "--shared-prefix", type=int, default=0, metavar="N",
        help="first N prompt tokens shared by every request (system prompt); "
        "followers hit the prefix cache and prefill only their tails",
    )
    ap.add_argument(
        "--prefill-chunk", type=int, default=32,
        help="max prompt tokens prefilled per engine step (chunked prefill)",
    )
    ap.add_argument("--no-prefix-cache", action="store_true")
    ap.add_argument(
        "--spec-k", type=int, default=0, metavar="K",
        help="speculative draft tokens per round (0 = plain decode)",
    )
    ap.add_argument(
        "--draft-bits", type=int, default=4, choices=(4, 8, 16),
        help="weight precision of the speculative draft passes",
    )
    ap.add_argument(
        "--temperature", type=float, default=0.0,
        help="sampling temperature (0 = greedy argmax)",
    )
    ap.add_argument(
        "--top-k", type=int, default=0,
        help="keep only the k highest logits before sampling (0 = disabled)",
    )
    ap.add_argument(
        "--top-p", type=float, default=1.0,
        help="nucleus sampling mass (1.0 = disabled)",
    )
    ap.add_argument(
        "--seed", type=int, default=0,
        help="sampling seed; request i uses seed + i, so a rerun with the "
        "same seed reproduces every stream exactly",
    )
    ap.add_argument(
        "--eos-id", type=int, default=None,
        help="stop token id: requests finish on emitting it (default: none)",
    )
    return ap


def main(argv: Optional[list[str]] = None) -> dict:
    args = build_parser().parse_args(argv)

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as model_lib
    from repro.serve import (
        GenerationOutput,
        PrecisionParams,
        SamplingParams,
        ServeEngine,
    )

    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()

    if args.no_quantize:
        mix = [16]
    elif args.precision_mix:
        mix = [int(b) for b in args.precision_mix.split(",")]
    else:
        mix = [args.w_bits or arch.serve_w_bits]
    kv_bits = args.kv_bits or arch.serve_kv_bits
    if args.shared_prefix >= args.prompt_len:
        raise SystemExit("--shared-prefix must be < --prompt-len")

    params = model_lib.init_params(arch, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens + arch.prefix_len + 8
    rng = np.random.default_rng(0)
    shared = rng.integers(0, arch.vocab, args.shared_prefix).astype(np.int32)

    def prompt() -> np.ndarray:
        tail = rng.integers(
            0, arch.vocab, args.prompt_len - args.shared_prefix
        ).astype(np.int32)
        return np.concatenate([shared, tail])

    if not ServeEngine.supports(arch):
        # recurrent-cache archs: static-wave fallback (single precision,
        # greedy-only) — refuse sampling flags rather than silently
        # reporting greedy results as sampled ones
        if args.temperature or args.top_k or args.top_p < 1.0 or args.seed:
            raise SystemExit(
                f"--temperature/--top-k/--top-p/--seed are not supported for "
                f"{arch.name} ({arch.family!r}): the static-wave fallback "
                "decodes greedily"
            )
        from repro.train.server import Request, Server

        srv = Server(
            arch, params, batch_size=args.batch_size, max_len=max_len,
            quantize=not args.no_quantize,
        )
        reqs = [
            Request(rid=i, prompt=prompt(), max_new_tokens=args.new_tokens)
            for i in range(args.requests)
        ]
        srv.serve(reqs)
        stats = srv.stats
        report = {
            "arch": arch.name,
            "scheduler": "static-wave (family not supported by paged engine)",
            "w_bits": arch.serve_w_bits if not args.no_quantize else 16,
            "requests": len(reqs),
            "tokens_out": stats.tokens_out,
            "prefill_s": round(stats.prefill_s, 3),
            "decode_s": round(stats.decode_s, 3),
            "decode_tok_per_s": round(
                stats.tokens_out / max(stats.decode_s, 1e-9), 1
            ),
            "outputs": [r.out_tokens[:16] for r in reqs],
        }
        print(json.dumps(report, indent=1))
        return report

    pages_per_slot = -(-max_len // args.page_size)
    engine = ServeEngine(
        arch, params,
        max_slots=args.batch_size,
        num_pages=args.batch_size * pages_per_slot,
        page_size=args.page_size,
        prefill_chunk=args.prefill_chunk,
        enable_prefix_cache=not args.no_prefix_cache,
        spec_k=args.spec_k,
        draft_bits=args.draft_bits,
    )
    reqs = [
        engine.submit(
            prompt(),
            SamplingParams(
                temperature=args.temperature,
                top_k=args.top_k,
                top_p=args.top_p,
                seed=args.seed + i,
                max_new_tokens=args.new_tokens,
                eos_id=args.eos_id,
            ),
            PrecisionParams(w_bits=mix[i % len(mix)], kv_bits=kv_bits),
        )
        for i in range(args.requests)
    ]
    # drive through the streaming API; the terminal outputs carry the streams
    outputs: dict[int, GenerationOutput] = {}
    stream_events = 0
    for ev in engine.generate(reqs):
        if isinstance(ev, GenerationOutput):
            outputs[ev.rid] = ev
        else:
            stream_events += 1
    stats = engine.stats
    ttfts = sorted(stats.ttfts)
    outs = [outputs[r.rid] for r in reqs]
    report = {
        "arch": arch.name,
        "w_bits_mix": mix,
        "kv_bits": kv_bits,
        "requests": len(reqs),
        "shared_prefix": args.shared_prefix,
        "temperature": args.temperature,
        "top_k": args.top_k,
        "top_p": args.top_p,
        "seed": args.seed,
        "tokens_out": stats.tokens_out,
        "stream_events": stream_events,
        "prefill_s": round(stats.prefill_s, 3),
        "prefill_chunks": stats.prefill_chunks,
        "decode_s": round(stats.decode_s, 3),
        "decode_tok_per_s": round(stats.decode_tok_per_s, 1),
        "ttft_ms_first": round(ttfts[0] * 1e3, 1) if ttfts else None,
        "ttft_ms_last": round(ttfts[-1] * 1e3, 1) if ttfts else None,
        "prefix_hit_rate": round(stats.prefix_hit_rate, 3),
        "prefix_hit_tokens": stats.prefix_hit_tokens,
        "decode_group_calls": {
            f"w{w}kv{k}": n for (w, k), n in stats.group_calls.items()
        },
        "mixed_precision_steps": stats.mixed_precision_steps,
        "mean_batch_occupancy": round(stats.mean_batch_occupancy, 2),
        "preemptions": stats.preemptions,
        "spec_k": args.spec_k,
        "spec_rounds": stats.spec_rounds,
        "spec_accept_rate": round(stats.spec_accept_rate, 3),
        "finish_reasons": [o.finish_reason for o in outs],
        "outputs": [list(o.tokens[:16]) for o in outs],
    }
    print(json.dumps(report, indent=1))
    return report


if __name__ == "__main__":
    main()
