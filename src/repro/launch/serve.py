"""Serving launcher: continuous-batching multi-precision quantized inference
(the paper's use case at traffic).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
        --requests 8 --new-tokens 16 --precision-mix 4,8

``--precision-mix`` assigns weight precisions to requests round-robin, so a
single engine decodes W4A16 and W8A16 requests in the same step (one batched
kernel call per precision group).  ``--w-bits`` forces one precision for all
requests (0 = arch default); ``--no-quantize`` serves raw bf16 weights.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4, help="concurrent slots")
    ap.add_argument("--page-size", type=int, default=16, help="KV page tokens")
    ap.add_argument("--w-bits", type=int, default=0, help="0 = arch default")
    ap.add_argument(
        "--precision-mix", default="",
        help="comma-separated w_bits cycled over requests, e.g. '4,8'",
    )
    ap.add_argument("--kv-bits", type=int, default=0, help="0 = arch default")
    ap.add_argument("--no-quantize", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import transformer as model_lib
    from repro.serve import ServeEngine

    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()

    if args.no_quantize:
        mix = [16]
    elif args.precision_mix:
        mix = [int(b) for b in args.precision_mix.split(",")]
    else:
        mix = [args.w_bits or arch.serve_w_bits]
    kv_bits = args.kv_bits or arch.serve_kv_bits

    params = model_lib.init_params(arch, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens + arch.prefix_len + 8
    rng = np.random.default_rng(0)

    if not ServeEngine.supports(arch):
        # recurrent-cache archs: static-wave fallback (single precision)
        from repro.train.server import Request, Server

        srv = Server(
            arch, params, batch_size=args.batch_size, max_len=max_len,
            quantize=not args.no_quantize,
        )
        reqs = [
            Request(
                rid=i,
                prompt=rng.integers(0, arch.vocab, args.prompt_len).astype(np.int32),
                max_new_tokens=args.new_tokens,
            )
            for i in range(args.requests)
        ]
        srv.serve(reqs)
        stats = srv.stats
        print(json.dumps({
            "arch": arch.name,
            "scheduler": "static-wave (family not supported by paged engine)",
            "w_bits": arch.serve_w_bits if not args.no_quantize else 16,
            "requests": len(reqs),
            "tokens_out": stats.tokens_out,
            "prefill_s": round(stats.prefill_s, 3),
            "decode_s": round(stats.decode_s, 3),
            "decode_tok_per_s": round(stats.tokens_out / max(stats.decode_s, 1e-9), 1),
            "sample_output": reqs[0].out_tokens[:8],
        }, indent=1))
        return

    pages_per_slot = -(-max_len // args.page_size)
    engine = ServeEngine(
        arch, params,
        max_slots=args.batch_size,
        num_pages=args.batch_size * pages_per_slot,
        page_size=args.page_size,
    )
    reqs = [
        engine.submit(
            rng.integers(0, arch.vocab, args.prompt_len).astype(np.int32),
            args.new_tokens,
            w_bits=mix[i % len(mix)],
            kv_bits=kv_bits,
        )
        for i in range(args.requests)
    ]
    engine.run()
    stats = engine.stats
    print(json.dumps({
        "arch": arch.name,
        "w_bits_mix": mix,
        "kv_bits": kv_bits,
        "requests": len(reqs),
        "tokens_out": stats.tokens_out,
        "prefill_s": round(stats.prefill_s, 3),
        "decode_s": round(stats.decode_s, 3),
        "decode_tok_per_s": round(stats.decode_tok_per_s, 1),
        "decode_group_calls": {f"w{w}kv{k}": n for (w, k), n in stats.group_calls.items()},
        "mixed_precision_steps": stats.mixed_precision_steps,
        "mean_batch_occupancy": round(stats.mean_batch_occupancy, 2),
        "preemptions": stats.preemptions,
        "sample_output": reqs[0].out_tokens[:8],
    }, indent=1))


if __name__ == "__main__":
    main()
