"""Multiplicity-corrected cost analysis of optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts each while-loop BODY
once, but our models scan over layers (deliberately — O(1) HLO size at 512
devices), so XLA's flops/bytes under-count by ~n_layers.  This module parses
the HLO text, walks the computation graph from ENTRY, multiplies while-body
contributions by their ``known_trip_count``, and produces:

  * flops            — dot/convolution FLOPs (2 x prod(out) x contraction)
  * hbm_bytes        — sum of (operand + output) bytes of every top-level,
                       memory-touching op (fusions, dots, copies, DUS...),
                       the same convention XLA's bytes-accessed uses
  * collective_bytes — per collective type, output-operand bytes

All values are per-device (the module is the per-device SPMD program).
Validated against analytic 6ND/2ND model FLOPs in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}
_SHAPE_RE = re.compile(r"\b([a-z]+\d+|pred|token)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_CALL_RE = re.compile(r"(?:calls|body|condition|branch_computations)=\{?%?([\w.\-,% ]+)\}?")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
# ops that are views / control only — no HBM traffic of their own
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "while", "conditional", "call", "after-all", "iota",
    "partition-id", "replica-id", "bitcast-convert",
}


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(type_str: str) -> tuple[list[int], str]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return [], "f32"
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims, m.group(1)


@dataclass
class Op:
    name: str
    type_str: str
    kind: str
    line: str
    operands: list[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)
    params: dict[str, str] = field(default_factory=dict)  # name -> type str


_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")


def _parse_operands(line: str, op_kind: str) -> list[str]:
    # operand list = first (...) group after the op name
    idx = line.find(op_kind + "(")
    if idx < 0:
        return []
    depth = 0
    start = idx + len(op_kind)
    out = []
    cur = []
    for ch in line[start:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                out.append("".join(cur).strip())
                break
        elif ch == "," and depth == 1:
            out.append("".join(cur).strip())
            cur = []
            continue
        cur.append(ch)
    names = []
    for o in out:
        m = re.search(r"%([\w.\-]+)\s*$", o) or re.search(r"%([\w.\-]+)", o)
        names.append(m.group(1) if m else o)
    return names


def _parse_op_line(stripped: str) -> Op | None:
    m = _NAME_RE.match(stripped)
    if not m:
        return None
    name = m.group(1)
    rest = re.sub(r"/\*.*?\*/", "", stripped[m.end():]).lstrip()
    if rest.startswith("("):  # tuple type: match parens
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        type_str, tail = rest[:end], rest[end:]
    else:
        mm = re.match(r"\S+", rest)
        if not mm:
            return None
        type_str, tail = mm.group(0), rest[mm.end():]
    km = re.match(r"\s*([\w\-]+)\(", tail)
    if not km:
        return None
    return Op(name=name, type_str=type_str, kind=km.group(1), line=stripped)


def parse_module(hlo: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name: str | None = None
    for raw in hlo.splitlines():
        stripped = raw.strip()
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$", stripped)
            if m:
                cur = Computation(m.group(2))
                if m.group(1):
                    entry_name = m.group(2)
                for pm in re.finditer(r"([\w.\-]+)\s*:\s*((?:\([^)]*\)|[^,)]+))", m.group(3)):
                    cur.params[pm.group(1)] = pm.group(2)
                comps[cur.name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        op = _parse_op_line(stripped)
        if op is not None:
            cur.ops.append(op)
    return comps, entry_name


def _dot_flops(op: Op, symbols: dict[str, str]) -> float:
    out_dims, _ = _shape_dims(op.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # contraction size from lhs shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    operands = _parse_operands(op.line, "dot")
    contract = 1
    if m and operands:
        lhs_type = symbols.get(operands[0], "")
        lhs_dims, _ = _shape_dims(lhs_type)
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    return 2.0 * out_elems * max(contract, 1)


def _conv_flops(op: Op, symbols: dict[str, str]) -> float:
    out_dims, _ = _shape_dims(op.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    operands = _parse_operands(op.line, "convolution")
    k = 1
    if len(operands) > 1:
        kd, _ = _shape_dims(symbols.get(operands[1], ""))
        for d in kd[:-1]:  # all but output-feature dim (approx)
            k *= d
    return 2.0 * out_elems * max(k, 1)


def analyze_hlo(hlo: str) -> dict:
    comps, entry_name = parse_module(hlo)
    entry = comps.get(entry_name) if entry_name else None
    if entry is None:
        for name, c in comps.items():
            if name.startswith("main") or entry is None:
                entry = c
    totals = {
        "flops": 0.0,
        "hbm_bytes": 0.0,
        "collective_bytes": {k: 0.0 for k in _COLLECTIVES},
        "while_loops": [],
    }
    visited: set[tuple[str, float]] = set()

    def walk(comp: Computation, mult: float) -> None:
        key = (comp.name, mult)
        # (a computation may be reused; walk each call site)
        symbols: dict[str, str] = dict(comp.params)
        for op in comp.ops:
            symbols[op.name] = op.type_str
        for op in comp.ops:
            kind = op.kind
            # descend into control flow
            if kind == "while":
                trip = 1.0
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = float(tm.group(1))
                cm = re.search(r"body=%?([\w.\-]+)", op.line)
                if cm and cm.group(1) in comps:
                    totals["while_loops"].append({"body": cm.group(1), "trip": trip})
                    walk(comps[cm.group(1)], mult * trip)
                continue
            if kind in ("call", "conditional", "async-start"):
                for cm in re.finditer(r"%([\w.\-]+)", op.line.split(kind + "(")[-1]):
                    if cm.group(1) in comps and "fused" not in cm.group(1):
                        walk(comps[cm.group(1)], mult)
                continue
            # collectives
            coll = None
            for c in _COLLECTIVES:
                if kind == c or kind.startswith(c + "-start"):
                    coll = c
                    break
            if coll:
                b = _shape_bytes(op.type_str)
                if kind.endswith("-start"):
                    b /= 2  # start ops carry (in, out) tuples
                totals["collective_bytes"][coll] += b * mult
                totals["hbm_bytes"] += b * mult
                continue
            if kind.endswith("-done"):
                continue
            # flops
            if kind == "dot":
                totals["flops"] += _dot_flops(op, symbols) * mult
            elif kind == "convolution":
                totals["flops"] += _conv_flops(op, symbols) * mult
            elif kind == "fusion":
                # count dots inside the fused computation (rare on CPU)
                cm = re.search(r"calls=%?([\w.\-]+)", op.line)
                if cm and cm.group(1) in comps:
                    fused = comps[cm.group(1)]
                    fsym = dict(fused.params)
                    for fop in fused.ops:
                        fsym[fop.name] = fop.type_str
                    for fop in fused.ops:
                        if fop.kind == "dot":
                            totals["flops"] += _dot_flops(fop, fsym) * mult
                        elif fop.kind == "convolution":
                            totals["flops"] += _conv_flops(fop, fsym) * mult
            # memory traffic
            if kind in _NO_TRAFFIC:
                continue
            if kind == "dynamic-update-slice":
                ops_ = _parse_operands(op.line, kind)
                upd = symbols.get(ops_[1], "") if len(ops_) > 1 else ""
                totals["hbm_bytes"] += 2 * _shape_bytes(upd) * mult
                continue
            b = _shape_bytes(op.type_str)
            for o in _parse_operands(op.line, kind):
                b += _shape_bytes(symbols.get(o, ""))
            totals["hbm_bytes"] += b * mult

    if entry is not None:
        walk(entry, 1.0)
    return totals
