"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --steps 300 --batch 8 --seq 512 [--reduced] [--mesh-data N --mesh-model M]

On a real TPU pod this binary runs per host (jax.distributed.initialize);
here it drives the same Trainer on whatever devices exist.  Sets the XLA
flags that let the latency-hiding scheduler overlap the per-microbatch
gradient collectives with compute.
"""
from __future__ import annotations

import os

# Compute/comm overlap: latency-hiding scheduler + async collectives.  Must be
# set before jax initializes.  (On TPU pods add
# --xla_enable_async_collective_permute / --xla_tpu_enable_async_all_gather.)
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_use_thunk_runtime=true",
)

import argparse
import dataclasses
import json

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", help="CPU-sized config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh-data", type=int, default=0, help="0 = no mesh (single device)")
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data.pipeline import DataConfig
    from repro.distributed import sharding as sh
    from repro.distributed.fault import run_with_restarts
    from repro.train import TrainConfig, Trainer

    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()
    arch = dataclasses.replace(arch, remat="none" if args.reduced else arch.remat)

    mesh = None
    if args.mesh_data:
        mesh = jax.make_mesh((args.mesh_data, args.mesh_model), ("data", "model"))
        sh.set_mesh(mesh)

    tc = TrainConfig(
        lr=args.lr,
        warmup=max(args.steps // 10, 1),
        total_steps=args.steps,
        microbatches=args.microbatches,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
    )
    data = DataConfig(vocab=arch.vocab, seq_len=args.seq, global_batch=args.batch)
    trainer = Trainer(arch=arch, tc=tc, data=data, mesh=mesh)

    def attempt(start_step: int) -> dict:
        return trainer.run(args.steps, start_step=start_step)

    out = run_with_restarts(
        attempt,
        max_restarts=3,
        on_restart=lambda n, e: print(f"[train] restart {n} after {e!r}"),
    )
    hist = out["history"]
    print(json.dumps({
        "arch": arch.name,
        "steps": len(hist),
        "first_loss": hist[0]["loss"],
        "final_loss": hist[-1]["loss"],
        "mean_step_s": sum(h["sec"] for h in hist) / max(len(hist), 1),
        "stragglers": trainer.monitor.stragglers,
    }, indent=1))


if __name__ == "__main__":
    main()
