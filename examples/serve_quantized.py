"""Multi-precision continuous-batching serving (the paper's deployment
story): W4A16, W8A16 and bf16 requests share ONE engine and decode in the
same engine steps — one batched kernel call per precision group — instead of
running three separate servers.

Run:  PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import ServeEngine

base = dataclasses.replace(
    get_config("yi-9b").reduced(), n_layers=4, d_model=256, d_ff=512,
    n_heads=4, n_kv_heads=2, head_dim=64, vocab=4096,
)
params = T.init_params(base, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

engine = ServeEngine(base, params, max_slots=6, num_pages=48, page_size=8)

# a mixed-precision request stream: per-request weight AND KV precision
SPEC = [(4, 8), (8, 8), (4, 8), (8, 8), (16, 16), (4, 8)]
reqs = [
    engine.submit(
        rng.integers(0, base.vocab, 12).astype(np.int32), 12,
        w_bits=w, kv_bits=kv,
    )
    for w, kv in SPEC
]
engine.run()

def payload_bytes(tree):
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree))

seen_w = sorted({w for w, _ in SPEC})
print(f"{'request':<10}{'weights':>10}{'kv':>6}   first tokens")
for r in reqs:
    assert r.done and len(r.out_tokens) == 12
    kv = "int8" if r.kv_bits == 8 else "bf16"
    print(f"req {r.rid:<6}w{r.w_bits:<9}{kv:<6}   {r.out_tokens[:6]}")

print(f"\nweight payload per precision (same model, one engine):")
for w in seen_w:
    print(f"  w{w:<3} {payload_bytes(engine.params_for(w)) / 1e6:8.1f} MB")

s = engine.stats
print(f"\nengine: {s.tokens_out} tokens, {s.decode_tok_per_s:.1f} decode tok/s, "
      f"mean batch occupancy {s.mean_batch_occupancy:.1f}")
print(f"decode kernel groups: "
      + ", ".join(f"w{w}/kv{k}x{n}" for (w, k), n in sorted(s.group_calls.items())))
print(f"engine steps decoding >=2 precision groups at once: {s.mixed_precision_steps}")
assert s.mixed_precision_steps > 0, "expected W4 and W8 requests in one decode batch"
print("\n(W4+W8+bf16 requests were continuously batched in one engine; "
      "w4 halves the w8 matmul-weight payload and greedy continuations stay "
      "consistent)")
