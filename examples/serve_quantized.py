"""Multi-precision quantized serving (the paper's deployment story):
compare W16 / W8 / W4 weights + int8 KV cache on the same model and prompts.

Run:  PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.train.server import Request, Server

base = dataclasses.replace(
    get_config("yi-9b").reduced(), n_layers=4, d_model=256, d_ff=512,
    n_heads=4, n_kv_heads=2, head_dim=64, vocab=4096,
)
params = T.init_params(base, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompts = [rng.integers(0, base.vocab, 12).astype(np.int32) for _ in range(4)]


def payload_bytes(tree):
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree))


print(f"{'mode':<10}{'weights MB':>12}{'tok/s':>8}   first tokens")
for bits, quant in ((16, False), (8, True), (4, True)):
    cfg = dataclasses.replace(base, serve_w_bits=bits)
    srv = Server(cfg, params, batch_size=4, max_len=64, quantize=quant)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=12) for i, p in enumerate(prompts)]
    t0 = time.perf_counter()
    srv.serve(reqs)
    dt = time.perf_counter() - t0
    mb = payload_bytes(srv.params) / 1e6
    print(f"w{bits:<9}{mb:>12.1f}{srv.stats.tokens_out/dt:>8.1f}   {reqs[0].out_tokens[:6]}")
print("\n(w4 halves the w8 payload; greedy continuations stay consistent)")
