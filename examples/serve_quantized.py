"""Multi-precision continuous-batching serving (the paper's deployment
story): W4A16, W8A16 and bf16 requests share ONE engine and decode in the
same engine steps — one batched kernel call per precision group — and
requests with the same system prompt share prefix-cache KV pages instead of
re-prefilling them (cross-precision isolated: a bf16 request must never read
int8 prefix pages, and W4-computed K/V never serves a W8 request).

Run:  PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import PrecisionParams, SamplingParams, ServeEngine

base = dataclasses.replace(
    get_config("yi-9b").reduced(), n_layers=4, d_model=256, d_ff=512,
    n_heads=4, n_kv_heads=2, head_dim=64, vocab=4096,
)
params = T.init_params(base, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

engine = ServeEngine(base, params, max_slots=6, num_pages=96, page_size=8)

# every request shares one 16-token system prompt + a unique 8-token tail
SYSTEM_PROMPT = rng.integers(0, base.vocab, 16).astype(np.int32)
def prompt():
    return np.concatenate([SYSTEM_PROMPT, rng.integers(0, base.vocab, 8).astype(np.int32)])

# wave 1 seeds the prefix cache: one request per (w_bits, kv_bits) group
SEED_SPEC = [(4, 8), (8, 8), (16, 16)]
for w, kv in SEED_SPEC:
    engine.submit(prompt(), SamplingParams(max_new_tokens=12), PrecisionParams(w_bits=w, kv_bits=kv))
    engine.run()
seeded_hits = engine.stats.prefix_hit_tokens
assert seeded_hits == 0, "disjoint precision groups must not share prefix pages"

# wave 2: same mixed-precision stream, warm prefix cache per group
SPEC = [(4, 8), (8, 8), (4, 8), (8, 8), (16, 16), (4, 8)]
reqs = [engine.submit(prompt(), SamplingParams(max_new_tokens=12), PrecisionParams(w_bits=w, kv_bits=kv)) for w, kv in SPEC]
engine.run()

def payload_bytes(tree):
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree))

seen_w = sorted({w for w, _ in SPEC})
print(f"{'request':<10}{'weights':>10}{'kv':>6}   first tokens")
for r in reqs:
    assert r.done and len(r.out_tokens) == 12
    kv = "int8" if r.kv_bits == 8 else "bf16"
    print(f"req {r.rid:<6}w{r.w_bits:<9}{kv:<6}   {r.out_tokens[:6]}")

print(f"\nweight payload per precision (same model, one engine):")
for w in seen_w:
    print(f"  w{w:<3} {payload_bytes(engine.params_for(w)) / 1e6:8.1f} MB")

s = engine.stats
print(f"\nengine: {s.tokens_out} tokens, {s.decode_tok_per_s:.1f} decode tok/s, "
      f"mean batch occupancy {s.mean_batch_occupancy:.1f}")
print(f"decode kernel groups: "
      + ", ".join(f"w{w}/kv{k}x{n}" for (w, k), n in sorted(s.group_calls.items())))
print(f"engine steps decoding >=2 precision groups at once: {s.mixed_precision_steps}")
assert s.mixed_precision_steps > 0, "expected W4 and W8 requests in one decode batch"

# every wave-2 request hit its own precision group's cached system prompt —
# and ONLY its own group's: the int8 pool serves w4 and w8 requests from
# *separate* page chains (hash-chain salt), bf16 from a separate pool.
print(f"\nprefix cache: hit rate {s.prefix_hit_rate:.0%} of admitted prompt "
      f"tokens ({s.prefix_hit_tokens} cached / {s.prefix_new_tokens} computed)")
for kv_bits in (8, 16):
    pc = engine.prefix_cache_for(kv_bits)
    print(f"  kv{kv_bits} pool: {pc.num_entries} cached blocks, "
          f"{pc.stats.evictions} evicted, {pc.stats.forks} CoW forks")
assert s.prefix_hit_tokens == 16 * len(SPEC), "warm wave should hit the full system prompt"

print("\n(W4+W8+bf16 requests were continuously batched in one engine; "
      "w4 halves the w8 matmul-weight payload, greedy continuations stay "
      "consistent, and the shared system prompt prefilled once per precision "
      "group — never across groups)")

# --- streaming sampled generation: the generate() API ----------------------
# per-request seeded sampling (temperature/top-p) with per-token streaming;
# the same seed reproduces the same stream, different seeds diverge.
from repro.serve import GenerationOutput, StreamEvent  # noqa: E402

def stream(seed):
    events, outs = [], []
    sampling = SamplingParams(temperature=0.8, top_p=0.95, seed=seed,
                              max_new_tokens=8)
    for ev in engine.generate([
        (prompt(), sampling, PrecisionParams(w_bits=4, kv_bits=8)),
    ]):
        if isinstance(ev, StreamEvent):
            events.append(ev.token)
        else:
            outs.append(ev)
    return events, outs

rng = np.random.default_rng(42)  # reset so both calls build the same prompt
toks_a, (out_a,) = stream(seed=7)
rng = np.random.default_rng(42)
toks_b, (out_b,) = stream(seed=7)
rng = np.random.default_rng(42)
toks_c, (out_c,) = stream(seed=8)

print(f"\nstreaming sampled generation (temperature 0.8, top-p 0.95):")
print(f"  seed 7:        {toks_a}  (finish: {out_a.finish_reason})")
print(f"  seed 7 again:  {toks_b}")
print(f"  seed 8:        {toks_c}")
assert isinstance(out_a, GenerationOutput) and list(out_a.tokens) == toks_a
assert toks_a == toks_b, "a fixed seed must reproduce the stream exactly"
assert toks_a != toks_c, "a different seed should diverge (w.h.p.)"
