"""Quickstart: the paper's technique in five minutes on CPU.

1. Bit-exact multi-precision arithmetic: a 16-bit MAC out of 4-bit multipliers
2. The custom ISA executing a convolution (FF and CF dataflows)
3. The mixed-dataflow selector on GoogLeNet layers
4. A quantized (int4/int8) matmul through the Pallas kernel path

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.assembler import assemble_conv
from repro.core.dataflow import ConvLayer
from repro.core.interpreter import run_program
from repro.core.isa import Dataflow, disassemble
from repro.core.perfmodel import evaluate_layer, select_dataflow
from repro.core.precision import Precision
from repro.core.sau import pe_multiply
from repro.kernels import ops

print("== 1. sixteen 4-bit multipliers == one 16-bit multiply ==")
a, b = -12345, 23456
got = int(pe_multiply(jnp.asarray([a]), jnp.asarray([b]), Precision.INT16)[0])
print(f"   {a} * {b} = {got} (direct: {a*b}) bit-exact={got == a*b}")

print("\n== 2. custom-ISA convolution (VSACFG/VSALD/VSAM) ==")
layer = ConvLayer("demo", cin=8, cout=8, k=3, h=6, w=6, stride=1, padding=1)
rng = np.random.default_rng(0)
x = rng.integers(-7, 8, (8, 6, 6)).astype(np.int32)
w = rng.integers(-7, 8, (8, 8, 3, 3)).astype(np.int32)
for df in (Dataflow.FF, Dataflow.CF):
    prog = assemble_conv(layer, x, w, Precision.INT4, df)
    out = run_program(prog)
    print(f"   {df.name}: {prog.n_instructions} instructions, "
          f"out[0,0,:3]={out[0,0,:3]}")
print("   first instructions:", [disassemble(wd) for wd in prog.words[:3]])

print("\n== 3. mixed dataflow selection (paper Fig. 3) ==")
for l in (ConvLayer("conv1x1", 480, 192, 1, 14, 14, 1, 0),
          ConvLayer("conv3x3", 96, 208, 3, 14, 14, 1, 1)):
    df = select_dataflow(l, Precision.INT16)
    perf = evaluate_layer(l, Precision.INT16, "mixed")
    print(f"   {l.name}: selector -> {df.name}, {perf.gops:.1f} GOPS "
          f"({perf.area_eff:.1f} GOPS/mm^2)")

print("\n== 4. multi-precision matmul kernel (W4A16, Pallas interpret) ==")
xf = jnp.asarray(rng.normal(size=(64, 512)), jnp.float32)
wf = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
wd, ws = ops.pack_weights(wf, 4)
y = ops.mpmm(xf, wd, ws, w_bits=4, dataflow="auto")
ref = xf @ wf
rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
print(f"   int4 weights: payload {wd.size} B (bf16 would be {wf.size*2} B), "
      f"rel quant error {rel:.3f}")
print("\nquickstart OK")
