"""The paper's own pipeline end-to-end: quantized CNN inference with the
mixed FF/CF dataflow strategy, reporting the per-layer decisions and the
modelled GOPS/area-efficiency for each benchmark network.

Run:  PYTHONPATH=src python examples/cnn_inference_speed.py [--net SqueezeNet]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core.perfmodel import SpeedModel, evaluate_network
from repro.core.precision import Precision
from repro.models.cnn import init_network, run_network
from repro.models.cnn_zoo import BENCHMARK_NETWORKS

ap = argparse.ArgumentParser()
ap.add_argument("--net", default="SqueezeNet", choices=list(BENCHMARK_NETWORKS))
ap.add_argument("--w-bits", type=int, default=8, choices=[4, 8])
ap.add_argument("--layers", type=int, default=6, help="execute first N layers numerically")
args = ap.parse_args()

layers, params = init_network(args.net, jax.random.PRNGKey(0), w_bits=args.w_bits)
print(f"{args.net}: {len(layers)} conv layers, w{args.w_bits} quantized")

# numerics on a downscaled input through the first N layers
x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3), jnp.float32)
out, decisions = run_network(
    args.net, x, params[: args.layers], layers[: args.layers], w_bits=args.w_bits
)
print(f"executed {args.layers} layers -> activation {out.shape}, "
      f"finite={bool(jnp.isfinite(out).all())}")
print("dataflow decisions:")
for d in decisions:
    print("   ", d)

# full-network modelled efficiency (the paper's metric)
for prec in (Precision.INT16, Precision.INT8, Precision.INT4):
    r = evaluate_network(layers, prec, "mixed", SpeedModel())
    print(f"modelled {prec.name}: {r['gops']:.1f} GOPS, "
          f"{r['area_eff']:.1f} GOPS/mm^2")
