"""End-to-end driver: train a ~124M-parameter llama-style model for a few
hundred steps on the synthetic pipeline, with checkpoints and crash-resume.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(CPU: ~0.5-2 s/step at these dims.)
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.distributed.fault import run_with_restarts
from repro.train import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--d-model", type=int, default=512)
ap.add_argument("--layers", type=int, default=8)
ap.add_argument("--seq", type=int, default=512)
ap.add_argument("--batch", type=int, default=8)
args = ap.parse_args()

# ~124M params: 8L x d512 + 32k vocab embeddings
arch = dataclasses.replace(
    get_config("llama3.2-3b"),
    name="llama-124m",
    n_layers=args.layers,
    d_model=args.d_model,
    n_heads=8,
    n_kv_heads=4,
    head_dim=64,
    d_ff=4 * args.d_model,
    vocab=32768,
    remat="none",
)
print(f"params ~= {arch.param_count()/1e6:.0f}M")

ckpt = tempfile.mkdtemp(prefix="repro_train_lm_")
tc = TrainConfig(
    lr=6e-4, warmup=30, total_steps=args.steps, microbatches=1,
    ckpt_every=100, ckpt_dir=ckpt, log_every=10,
)
data = DataConfig(vocab=arch.vocab, seq_len=args.seq, global_batch=args.batch)
tr = Trainer(arch=arch, tc=tc, data=data)

out = run_with_restarts(lambda s: tr.run(args.steps, start_step=s), max_restarts=2)
hist = out["history"]
for h in hist[:: max(len(hist) // 15, 1)]:
    flag = " STRAGGLER" if h["straggler"] else ""
    print(f"step {h['step']:4d} loss {h['loss']:.4f} ({h['sec']:.2f}s){flag}")
print(f"\nfinal loss {hist[-1]['loss']:.4f} (from {hist[0]['loss']:.4f}); "
      f"checkpoints in {ckpt}")
assert hist[-1]["loss"] < hist[0]["loss"]
