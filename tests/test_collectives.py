"""int8 compressed gradient reduction: fidelity, error feedback, wire bytes."""
import jax.numpy as jnp
import numpy as np

from repro.distributed.collectives import _CHUNK, _dequantize_chunks, _quantize_chunks


def test_chunk_quantize_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4096,)) * 10, jnp.float32)
    q, s = _quantize_chunks(x, n_shards=4)
    assert q.dtype == jnp.int8
    back = _dequantize_chunks(q, s, 4096)
    # error bounded by scale/2 per chunk
    err = np.abs(np.asarray(back - x))
    bound = np.repeat(np.asarray(s).reshape(-1), _CHUNK)[:4096] / 2 + 1e-6
    assert (err <= bound).all()


def test_wire_bytes_are_quarter_fp32():
    x = jnp.zeros((1 << 16,), jnp.float32)
    q, s = _quantize_chunks(x, n_shards=8)
    wire = q.size * 1 + s.size * 4
    assert wire < 0.3 * x.size * 4


def test_compressed_psum_mean_multidevice(subproc):
    subproc(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import compressed_psum_mean

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        per_shard = jnp.asarray(rng.normal(size=(8, 4096)) * 5, jnp.float32)

        def local(x):
            g = x[0]  # my shard's gradient
            red = compressed_psum_mean(g, "data")
            exact = jax.lax.pmean(g, "data")
            return red[None], exact[None]

        red, exact = shard_map(
            local, mesh=mesh, in_specs=P("data", None),
            out_specs=(P("data", None), P("data", None)), check_rep=False,
        )(per_shard)
        red, exact = np.asarray(red), np.asarray(exact)
        # every shard got the same reduced value
        assert np.allclose(red, red[0], atol=1e-6)
        # compressed mean close to exact mean (two int8 stages)
        scale = np.abs(exact).max()
        assert np.abs(red - exact).max() < 0.05 * scale, np.abs(red-exact).max()
        print("compressed psum OK", np.abs(red - exact).max())
        """,
        n_devices=8,
    )


def test_error_feedback_reduces_bias(subproc):
    """With error feedback, repeated reductions of the SAME gradient converge
    to the exact mean (the residual re-enters each round)."""
    subproc(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import compressed_psum_mean

        mesh = jax.make_mesh((4,), ("data",))
        rng = np.random.default_rng(1)
        g_all = jnp.asarray(rng.normal(size=(4, 2048)), jnp.float32)

        def local(g_shard):
            g = g_shard[0]
            e = jnp.zeros_like(g)
            e2 = jnp.zeros((1, 1024), jnp.float32)  # 2048/4 shards -> 512 pad 1024
            exact = jax.lax.pmean(g, "data")
            errs = []
            acc = jnp.zeros_like(g)   # what the optimizer accumulated
            acc_exact = jnp.zeros_like(g)
            for _ in range(6):
                red, e, e2 = compressed_psum_mean(g + e, "data", e2)
                acc = acc + red
                acc_exact = acc_exact + exact
                errs.append(jnp.max(jnp.abs(acc - acc_exact)))
            return jnp.stack(errs)[None]

        errs = shard_map(local, mesh=mesh, in_specs=P("data", None),
                         out_specs=P("data", None), check_rep=False)(g_all)
        errs = np.asarray(errs)[0]
        # with two-stage error feedback the cumulative sum telescopes: the
        # error must NOT grow ~linearly with rounds
        assert errs[-1] < 2.0 * errs[0] + 1e-4, errs
        print("error feedback OK", errs)
        """,
        n_devices=4,
    )
