"""The serving CLI (repro.launch.serve): argument parsing, a tiny
end-to-end run through the streaming generate() path, and fixed-seed
determinism of the sampled streams (same seed twice => identical outputs).
"""
import json

import pytest

from repro.launch.serve import build_parser, main

E2E_ARGS = [
    "--arch", "yi-9b", "--reduced",
    "--requests", "2", "--prompt-len", "6", "--new-tokens", "4",
    "--batch-size", "2", "--page-size", "8",
]


# ---------------------------------------------------------------- arg parsing
def test_parser_defaults():
    args = build_parser().parse_args(["--arch", "yi-9b"])
    assert args.arch == "yi-9b" and not args.reduced
    assert args.requests == 4 and args.new_tokens == 16
    assert args.w_bits == 0 and args.kv_bits == 0  # 0 = arch default
    assert args.precision_mix == "" and args.spec_k == 0
    # sampling defaults: greedy, no masks, seed 0
    assert args.temperature == 0.0
    assert args.top_k == 0 and args.top_p == 1.0 and args.seed == 0
    assert args.eos_id is None


def test_parser_sampling_and_spec_flags():
    args = build_parser().parse_args([
        "--arch", "llama3.2-3b", "--reduced",
        "--temperature", "0.8", "--top-k", "50", "--top-p", "0.9",
        "--seed", "3", "--spec-k", "2", "--draft-bits", "8",
        "--precision-mix", "4,8", "--eos-id", "7",
    ])
    assert args.temperature == 0.8 and args.top_k == 50 and args.top_p == 0.9
    assert args.seed == 3 and args.spec_k == 2 and args.draft_bits == 8
    assert args.precision_mix == "4,8" and args.eos_id == 7


def test_parser_requires_arch(capsys):
    with pytest.raises(SystemExit):
        build_parser().parse_args([])
    capsys.readouterr()  # swallow argparse usage noise


def test_shared_prefix_must_be_shorter_than_prompt():
    with pytest.raises(SystemExit, match="shared-prefix"):
        main(["--arch", "yi-9b", "--reduced",
              "--prompt-len", "8", "--shared-prefix", "8"])


def test_sampling_flags_rejected_on_static_wave_fallback():
    """Recurrent-cache archs fall back to the greedy-only wave server; the
    CLI must refuse sampling flags instead of silently reporting greedy
    output as sampled."""
    with pytest.raises(SystemExit, match="static-wave"):
        main(["--arch", "mamba2-130m", "--reduced",
              "--temperature", "0.8", "--seed", "7"])


# ------------------------------------------------------------- end to end
def test_cli_end_to_end_greedy(capsys):
    report = main(E2E_ARGS + ["--precision-mix", "4,8"])
    assert report["requests"] == 2
    assert report["tokens_out"] == 8
    assert report["stream_events"] == 8  # one StreamEvent per token
    assert report["finish_reasons"] == ["length", "length"]
    assert [len(o) for o in report["outputs"]] == [4, 4]
    assert report["w_bits_mix"] == [4, 8]
    assert report["decode_tok_per_s"] > 0
    # the report is also printed as valid JSON
    printed = json.loads(capsys.readouterr().out)
    assert printed["outputs"] == report["outputs"]


def test_cli_seed_determinism(capsys):
    """Same seed twice => bit-identical streams; a different seed diverges."""
    sampled = E2E_ARGS + ["--temperature", "0.8", "--top-p", "0.95"]
    a = main(sampled + ["--seed", "123"])
    b = main(sampled + ["--seed", "123"])
    c = main(sampled + ["--seed", "124"])
    capsys.readouterr()
    assert a["outputs"] == b["outputs"]
    assert a["outputs"] != c["outputs"]  # w.h.p. on a 512-vocab model
    # distinct per-request seeds (seed + i): identical prompts would still
    # diverge between requests; here prompts differ too, so just sanity-check
    # the two requests' streams are not identical
    assert a["outputs"][0] != a["outputs"][1]
