"""SSD (Mamba2) correctness: chunked == naive recurrence == decode steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (
    init_ssm_params,
    ssd_chunked,
    ssm_block,
    ssm_block_with_state,
    ssm_decode_step,
    ssm_dims,
)

RNG = np.random.default_rng(0)


def naive_ssd(x, dt, a_log, b, c):
    """Direct recurrence oracle: h_t = a_t h_{t-1} + dt_t B_t (x) x_t."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    a = -np.exp(np.asarray(a_log))
    hstate = np.zeros((bs, h, p, n))
    ys = np.zeros((bs, s, h, p))
    xn, dtn, bn, cn = map(np.asarray, (x, dt, b, c))
    for t in range(s):
        decay = np.exp(dtn[:, t] * a[None, :])  # [B, H]
        upd = np.einsum("bn,bhp,bh->bhpn", bn[:, t], xn[:, t], dtn[:, t])
        hstate = hstate * decay[:, :, None, None] + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", cn[:, t], hstate)
    return ys, hstate


def _case(bs=2, s=96, h=3, p=8, n=4, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(bs, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(bs, s, h))) * 0.5, jnp.float32)
    a_log = jnp.asarray(rng.normal(size=(h,)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(bs, s, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(bs, s, n)), jnp.float32)
    return x, dt, a_log, b, c


@pytest.mark.parametrize("chunk", [16, 32, 96, 128])
def test_chunked_equals_recurrence(chunk):
    x, dt, a_log, b, c = _case()
    y = ssd_chunked(x, dt, a_log, b, c, chunk=chunk)
    y_exp, _ = naive_ssd(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(y), y_exp, atol=1e-4, rtol=1e-4)


def test_chunk_size_invariance():
    x, dt, a_log, b, c = _case(s=64)
    y1 = ssd_chunked(x, dt, a_log, b, c, chunk=8)
    y2 = ssd_chunked(x, dt, a_log, b, c, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)


def test_final_state_matches_recurrence():
    x, dt, a_log, b, c = _case(s=40)  # not a chunk multiple: exercises padding
    _, st = ssd_chunked(x, dt, a_log, b, c, chunk=16, return_state=True)
    _, st_exp = naive_ssd(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(st), st_exp, atol=1e-4, rtol=1e-4)


def test_block_prefill_then_decode_consistent():
    dims = ssm_dims(d_model=64, state=8, head_p=16)
    params = init_ssm_params(jax.random.PRNGKey(0), dims, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 33, 64)), jnp.float32)
    # full-sequence output
    y_full = ssm_block(params, x, dims, chunk=16)
    # prefill on the first 32, then one decode step
    y_pre, state = ssm_block_with_state(params, x[:, :32], dims, chunk=16)
    y_step, _ = ssm_decode_step(params, x[:, 32:33], state, dims)
    np.testing.assert_allclose(
        np.asarray(y_full[:, :32]), np.asarray(y_pre), atol=1e-4, rtol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(y_full[:, 32:33]), np.asarray(y_step), atol=1e-4, rtol=1e-4
    )


def test_decay_bounds():
    """Negative A keeps |decay| <= 1: long-context state cannot blow up."""
    x, dt, a_log, b, c = _case(s=256, seed=3)
    y = ssd_chunked(x, dt, a_log, b, c, chunk=32)
    assert np.isfinite(np.asarray(y)).all()
