"""Training loop: convergence, crash-resume exactness, straggler detection."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.distributed.fault import StepMonitor, run_with_restarts
from repro.train import TrainConfig, Trainer


def _tiny(tmp_path, name="llama3.2-3b", total=30, microbatches=1):
    arch = dataclasses.replace(
        get_config(name).reduced(), n_layers=2, d_model=64, d_ff=128, vocab=256,
        n_heads=2, n_kv_heads=2, head_dim=32,
    )
    tc = TrainConfig(
        lr=3e-3, warmup=5, total_steps=total, ckpt_every=10,
        ckpt_dir=str(tmp_path), microbatches=microbatches, grad_clip=1.0,
    )
    data = DataConfig(vocab=arch.vocab, seq_len=64, global_batch=8)
    return Trainer(arch=arch, tc=tc, data=data)


def test_loss_decreases(tmp_path):
    tr = _tiny(tmp_path)
    out = tr.run(30)
    losses = [h["loss"] for h in out["history"]]
    assert np.mean(losses[-5:]) < 0.8 * np.mean(losses[:5]), (losses[:5], losses[-5:])


def test_grad_accumulation_equivalent(tmp_path):
    """microbatches=2 produces (nearly) the same trajectory as microbatches=1."""
    t1 = _tiny(tmp_path / "a")
    out1 = t1.run(5)
    t2 = _tiny(tmp_path / "b", microbatches=2)
    out2 = t2.run(5)
    l1 = [h["loss"] for h in out1["history"]]
    l2 = [h["loss"] for h in out2["history"]]
    np.testing.assert_allclose(l1, l2, rtol=2e-2)


def test_crash_resume_matches_uninterrupted(tmp_path):
    ref = _tiny(tmp_path / "ref")
    out_ref = ref.run(20)

    crash = _tiny(tmp_path / "crash")

    def attempt(start):
        return crash.run(20, start_step=start, fail_at=13 if start != -1 else None)

    result = run_with_restarts(attempt, max_restarts=2)
    # resumed run end state equals uninterrupted run end state exactly:
    # (same data replay, same checkpointed state at step 10)
    ra = jax.tree_util.tree_leaves(out_ref["params"])
    rb = jax.tree_util.tree_leaves(result["params"])
    for a, b in zip(ra, rb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor():
    m = StepMonitor(ema_decay=0.5, deadline_factor=2.0, warmup_steps=1)
    assert not m.observe(0, 1.0)
    assert not m.observe(1, 1.1)
    assert m.observe(2, 5.0)  # 5s >> 2x EMA
    assert m.stragglers == [2]
    # EMA not poisoned by the straggler
    assert m.ema < 1.2


def test_run_with_restarts_bounded():
    calls = []

    def always_fails(start):
        calls.append(start)
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        run_with_restarts(always_fails, max_restarts=2)
    assert len(calls) == 3  # initial + 2 retries
