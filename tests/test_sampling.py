"""Seeded stochastic sampling across the serving engine.

Op level: top-k/top-p mask + temperature distribution vs a numpy oracle,
greedy == exact argmax, empirical sample distribution vs the exact probs.
Engine level: fixed-seed determinism, batch-composition independence,
preempt-resume equivalence, the deprecated submit() kwargs shim, and the
streaming generate() contract.  Speculative rejection sampling: greedy
one-hot collapse (exact equality) and chi-squared agreement of the emitted
marginal with the target distribution at n >= 10k sampled tokens.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.models import transformer as T
from repro.serve import (
    GenerationOutput,
    PrecisionParams,
    SamplingParams,
    ServeEngine,
    StreamEvent,
)
from repro.serve.spec_decode import SALT_DRAFT, rejection_sample

# chi-squared critical values at alpha = 1e-3 (Wilson-Hilferty), keyed by
# degrees of freedom — no scipy in the test environment
CHI2_CRIT = {7: 24.32, 15: 37.70, 31: 61.10}


def _cfg(**kw):
    base = dataclasses.replace(
        get_config("llama3.2-3b").reduced(),
        n_layers=2, d_model=64, d_ff=128, vocab=256, n_heads=4, n_kv_heads=2,
        head_dim=16, serve_kv_bits=8,
    )
    return dataclasses.replace(base, **kw)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, n_slots=4, num_pages=64, **kw):
    return ServeEngine(
        cfg, params, max_slots=n_slots, num_pages=num_pages, page_size=4, **kw
    )


def _sampled(seed, new_tokens=6, **kw):
    return SamplingParams(
        temperature=0.8, top_p=0.95, seed=seed, max_new_tokens=new_tokens, **kw
    )


# ------------------------------------------------------------ op-level masks
def _np_sampling_probs(logits, temp, top_k, top_p):
    """Numpy oracle for ops.sampling_probs (single row)."""
    v = logits.shape[-1]
    if temp <= 0:
        out = np.zeros(v)
        out[np.argmax(logits)] = 1.0
        return out
    keep = np.ones(v, bool)
    if top_k > 0:
        kth = np.sort(logits)[::-1][min(top_k, v) - 1]
        keep &= logits >= kth
    x = np.where(keep, logits, -np.inf)
    p = np.exp(x - x.max())
    p /= p.sum()
    order = np.argsort(x)[::-1]
    cum = np.cumsum(p[order])
    keep_p = np.zeros(v, bool)
    keep_p[order] = ((cum - p[order]) < top_p) | (top_p >= 1.0)
    x = np.where(keep & keep_p, logits, -np.inf)
    x = x / temp
    p = np.exp(x - x.max())
    return p / p.sum()


@pytest.mark.parametrize(
    "temp,top_k,top_p",
    [(1.0, 0, 1.0), (0.7, 5, 1.0), (1.3, 0, 0.9), (0.8, 10, 0.5),
     (2.0, 3, 0.95), (0.0, 5, 0.5)],
)
def test_sampling_probs_matches_numpy_oracle(temp, top_k, top_p):
    rng = np.random.default_rng(0)
    b, v = 8, 64
    logits = rng.standard_normal((b, v)).astype(np.float32) * 2.0
    got = np.asarray(
        ops.sampling_probs(
            jnp.asarray(logits),
            jnp.full(b, temp, jnp.float32),
            jnp.full(b, top_k, jnp.int32),
            jnp.full(b, top_p, jnp.float32),
        )
    )
    want = np.stack(
        [_np_sampling_probs(logits[i], temp, top_k, top_p) for i in range(b)]
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_greedy_rows_are_exact_argmax():
    """temperature == 0 must return the raw argmax bit-for-bit, whatever the
    keys and masks say — the engine's greedy golden streams depend on it."""
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.standard_normal((16, 128)), jnp.float32)
    keys = ops.sample_keys(
        jnp.arange(16, dtype=jnp.uint32), jnp.arange(16, dtype=jnp.int32)
    )
    toks = ops.sample_tokens(
        logits, keys,
        jnp.zeros(16, jnp.float32),  # greedy
        jnp.full(16, 3, jnp.int32), jnp.full(16, 0.5, jnp.float32),
    )
    np.testing.assert_array_equal(
        np.asarray(toks), np.asarray(jnp.argmax(logits, axis=-1))
    )


def test_sample_tokens_distribution_matches_probs():
    """Empirical distribution of sample_tokens over many keys chi-squares
    against the exact sampling_probs distribution (top-k + top-p active)."""
    rng = np.random.default_rng(2)
    v, n = 16, 20000
    logits = rng.standard_normal(v).astype(np.float32)
    temp, top_k, top_p = 0.9, 12, 0.95
    tiled = jnp.tile(jnp.asarray(logits)[None], (n, 1))
    keys = ops.sample_keys(
        jnp.arange(n, dtype=jnp.uint32), jnp.zeros(n, jnp.int32)
    )
    toks = np.asarray(
        ops.sample_tokens(
            tiled, keys,
            jnp.full(n, temp, jnp.float32),
            jnp.full(n, top_k, jnp.int32),
            jnp.full(n, top_p, jnp.float32),
        )
    )
    expect = _np_sampling_probs(logits, temp, top_k, top_p) * n
    counts = np.bincount(toks, minlength=v).astype(np.float64)
    live = expect > 0
    assert counts[~live].sum() == 0  # masked tokens never sampled
    chi2 = np.sum((counts[live] - expect[live]) ** 2 / expect[live])
    assert chi2 < CHI2_CRIT[15], f"chi2 {chi2:.1f} (dof<=15)"


def test_disabled_top_p_masks_nothing_even_under_f32_rounding():
    """top_p == 1.0 (disabled) must keep every token even when a head-heavy
    distribution makes the f32 exclusive-cumulative mass round to exactly
    1.0 — the masked graph (forced by a batch-mate's top_p < 1) must equal
    the elided graph, or batch composition would leak into streams."""
    v = 1024
    logits = np.full(v, -14.0, np.float32)
    logits[0] = 10.0  # ~all mass on token 0, tail mass rounds cum to 1.0
    l2 = jnp.asarray(np.stack([logits, logits]))
    temps = jnp.full(2, 1.0, jnp.float32)
    masked = ops.sampling_probs(
        l2, temps, jnp.zeros(2, jnp.int32),
        jnp.asarray([1.0, 0.9], jnp.float32),  # row 0 disabled, row 1 active
    )
    elided = ops.sampling_probs(l2, temps, None, None)
    np.testing.assert_array_equal(
        np.asarray(masked[0]), np.asarray(elided[0])
    )
    assert int((np.asarray(masked[0]) > 0).sum()) == v  # nothing masked


def test_seed_must_fit_uint32():
    """_samp_arrays packs seeds into np.uint32; an oversized seed must be
    rejected at SamplingParams construction, not crash the engine mid-serve."""
    with pytest.raises(ValueError, match="uint32"):
        SamplingParams(seed=2**33)
    SamplingParams(seed=2**32 - 1)  # max valid


def test_sample_keys_are_position_and_salt_separated():
    seeds = jnp.asarray([3, 3, 3, 4], jnp.uint32)
    pos = jnp.asarray([0, 0, 1, 0], jnp.int32)
    a = np.asarray(ops.sample_keys(seeds, pos, salt=0))
    b = np.asarray(ops.sample_keys(seeds, pos, salt=1))
    assert (a[0] == a[1]).all()  # same (seed, pos, salt) -> same key
    assert (a[0] != a[2]).any()  # position separates
    assert (a[0] != a[3]).any()  # seed separates
    assert (a[0] != b[0]).any()  # salt separates


# ------------------------------------------------------- engine determinism
def test_fixed_seed_determinism_and_batch_independence(setup):
    """The same seeds replay the same sampled streams run-to-run, and a
    request's stream is identical whether it decodes solo or batched with
    strangers (position-keyed PRNG, batch-independent logits)."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32) for _ in range(3)]
    seeds = [11, 22, 33]

    def run_batch():
        eng = _engine(cfg, params, n_slots=3)
        reqs = [
            eng.submit(p, _sampled(s), PrecisionParams(w_bits=8, kv_bits=8))
            for p, s in zip(prompts, seeds)
        ]
        eng.run()
        return [r.out_tokens for r in reqs]

    first = run_batch()
    assert run_batch() == first  # run-to-run reproducible
    assert len(set(map(tuple, first))) == 3  # different seeds diverge (w.h.p.)
    for i in range(3):  # solo == batched, token for token
        eng = _engine(cfg, params, n_slots=1)
        solo = eng.submit(
            prompts[i], _sampled(seeds[i]), PrecisionParams(w_bits=8, kv_bits=8)
        )
        eng.run()
        assert solo.out_tokens == first[i], f"request {i}"


def test_preempt_resume_sampled_equivalence(setup):
    """A preempted sampled request recomputes its cache and *redraws* its
    continuation with the same position keys: the stream must equal the
    undisturbed run's, token for token."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, 10).astype(np.int32) for _ in range(3)]

    def run(num_pages):
        eng = _engine(cfg, params, n_slots=3, num_pages=num_pages)
        reqs = [
            eng.submit(
                p, _sampled(50 + i, new_tokens=8),
                PrecisionParams(w_bits=8, kv_bits=8),
            )
            for i, p in enumerate(prompts)
        ]
        eng.run()
        return eng, [r.out_tokens for r in reqs]

    tight_eng, tight = run(num_pages=10)  # pool too small: preempt + replay
    roomy_eng, roomy = run(num_pages=64)
    assert tight_eng.stats.preemptions > 0
    assert roomy_eng.stats.preemptions == 0
    assert tight == roomy


def test_submit_legacy_kwargs_shim(setup):
    """The deprecated flat-kwargs signature still works (warning once) and
    produces the identical request the structured form does."""
    cfg, params = setup
    prompt = np.arange(1, 9, dtype=np.int32)
    eng = _engine(cfg, params)
    with pytest.warns(DeprecationWarning, match="SamplingParams"):
        old = eng.submit(prompt, 5, w_bits=8, kv_bits=8, eos_id=7,
                         stop_tokens=(9,), spec_k=2, draft_bits=4)
    new = eng.submit(
        prompt,
        SamplingParams(max_new_tokens=5, eos_id=7, stop_tokens=(9,)),
        PrecisionParams(w_bits=8, kv_bits=8, spec_k=2, draft_bits=4),
    )
    for f in ("max_new_tokens", "w_bits", "kv_bits", "eos_id", "stop_tokens",
              "spec_k", "draft_bits", "temperature", "top_k", "top_p", "seed"):
        assert getattr(old, f) == getattr(new, f), f
    # structured + conflicting flat kwargs is an error, not a silent merge
    with pytest.warns(DeprecationWarning):
        with pytest.raises(TypeError, match="PrecisionParams"):
            eng.submit(prompt, SamplingParams(), PrecisionParams(), w_bits=4)
    with pytest.raises(TypeError, match="unexpected"):
        with pytest.warns(DeprecationWarning):
            eng.submit(prompt, 5, nonsense_kwarg=1)


def test_generate_streams_every_token_then_terminal_output(setup):
    """generate() yields each token exactly once, in order, with the
    finish_reason on the last event, then the terminal GenerationOutput."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32) for _ in range(2)]
    eng = _engine(cfg, params)
    reqs = [
        eng.submit(prompts[0], SamplingParams(max_new_tokens=5)),
        eng.submit(prompts[1], _sampled(9, new_tokens=7)),
    ]
    events: dict[int, list[StreamEvent]] = {r.rid: [] for r in reqs}
    outputs: dict[int, GenerationOutput] = {}
    for ev in eng.generate(reqs):
        if isinstance(ev, StreamEvent):
            assert ev.rid not in outputs, "token after terminal output"
            events[ev.rid].append(ev)
        else:
            outputs[ev.rid] = ev
    for r in reqs:
        evs = events[r.rid]
        assert [e.token for e in evs] == r.out_tokens
        assert [e.index for e in evs] == list(range(len(r.out_tokens)))
        assert all(e.finish_reason is None for e in evs[:-1])
        assert evs[-1].finish_reason == "length" and evs[-1].is_last
        out = outputs[r.rid]
        assert list(out.tokens) == r.out_tokens
        assert out.finish_reason == "length" and out.ttft is not None
    # a stopped request reports finish_reason == "stop" with the token kept
    eos = reqs[0].out_tokens[2]
    eng2 = _engine(cfg, params)
    outs = [
        ev for ev in eng2.generate(
            [(prompts[0], SamplingParams(max_new_tokens=5, eos_id=eos))]
        )
        if isinstance(ev, GenerationOutput)
    ]
    assert outs[0].finish_reason == "stop"
    assert outs[0].tokens[-1] == eos


def test_generate_failed_request_yields_failed_output(setup):
    cfg, params = setup
    eng = _engine(cfg, params, n_slots=1, num_pages=4)
    with pytest.raises(ValueError, match="never fit"):
        eng.submit(np.arange(8, dtype=np.int32), SamplingParams(max_new_tokens=64))
    ok = eng.submit(np.arange(4, dtype=np.int32), SamplingParams(max_new_tokens=2))
    from repro.serve import ServeRequest

    big = ServeRequest(rid=99, prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=64, w_bits=8, kv_bits=8, arrival=10**6)
    eng._sched.submit(big)
    outs = {
        ev.rid: ev for ev in eng.generate([ok, big])
        if isinstance(ev, GenerationOutput)
    }
    assert outs[ok.rid].finish_reason == "length"
    assert outs[99].finish_reason == "failed"
    assert outs[99].tokens == () and "never fit" in outs[99].error


# ------------------------------------------- speculative rejection sampling
def test_rejection_sample_greedy_onehots_collapse_to_equality():
    """One-hot draft/target distributions (greedy rows) must reproduce the
    exact-equality acceptance rule: accept while the draft equals the target
    argmax, then emit the target argmax at the cut."""
    v, k = 8, 3
    tgt_ids = np.array([2, 5, 1, 4])  # target argmax at each window slot
    for n_match in range(k + 1):
        drafts = np.array(
            [[tgt_ids[i] if i < n_match else (tgt_ids[i] + 1) % v
              for i in range(k)]]
        )
        qd = np.zeros((1, k, v), np.float32)
        qd[0, np.arange(k), drafts[0]] = 1.0
        qt = np.zeros((1, k + 1, v), np.float32)
        qt[0, np.arange(k + 1), tgt_ids] = 1.0
        tokens, accept = rejection_sample(
            jnp.asarray(drafts), jnp.asarray(qd), jnp.asarray(qt),
            jnp.asarray([123], jnp.uint32), jnp.asarray([0], jnp.int32),
            jnp.asarray([k], jnp.int32),
        )
        assert int(accept[0]) == n_match
        got = [int(t) for t in np.asarray(tokens)[0, : n_match + 1]]
        assert got == list(tgt_ids[: n_match + 1]), f"n_match={n_match}"


def test_spec_sampled_marginal_matches_target_chi2():
    """Speculative rejection sampling's emitted first token must be
    distributed exactly as the target distribution (Leviathan et al.):
    chi-squared over n = 20k sampled windows on a toy draft/target pair."""
    v, k, n = 8, 2, 20000
    rng = np.random.default_rng(6)
    qd0 = rng.random(v).astype(np.float32) + 0.05
    qd0 /= qd0.sum()
    qt0 = rng.random(v).astype(np.float32) + 0.05
    qt0 /= qt0.sum()
    seeds = jnp.arange(n, dtype=jnp.uint32)
    pos0 = jnp.zeros(n, jnp.int32)
    # drafts drawn exactly as spec_decode_round draws them: from the draft
    # distribution with the (seed, pos, SALT_DRAFT) key
    d0 = ops.sample_from_probs(
        jnp.tile(jnp.asarray(qd0)[None], (n, 1)),
        ops.sample_keys(seeds, pos0, SALT_DRAFT),
    )
    d1 = ops.sample_from_probs(
        jnp.tile(jnp.asarray(qd0)[None], (n, 1)),
        ops.sample_keys(seeds, pos0 + 1, SALT_DRAFT),
    )
    drafts = jnp.stack([d0, d1], axis=1)
    qd = jnp.tile(jnp.asarray(qd0)[None, None], (n, k, 1))
    qt = jnp.tile(jnp.asarray(qt0)[None, None], (n, k + 1, 1))
    tokens, accept = rejection_sample(
        drafts, qd, qt, seeds, pos0, jnp.full(n, k, jnp.int32)
    )
    emitted = np.asarray(tokens)[:, 0]  # first emitted token of each window
    counts = np.bincount(emitted, minlength=v).astype(np.float64)
    expect = qt0.astype(np.float64) * n
    chi2 = np.sum((counts - expect) ** 2 / expect)
    assert chi2 < CHI2_CRIT[7], f"chi2 {chi2:.1f} vs target marginal (dof 7)"
    # expected accept length: slots accept independently w.p.
    # a = sum(min(qd, qt)), and accept is the leading run of successes, so
    # E[accept] = a + a^2 + ... + a^k
    a = float(np.minimum(qd0, qt0).sum())
    expected_len = sum(a ** i for i in range(1, k + 1))
    assert abs(float(np.asarray(accept).mean()) - expected_len) < 0.02 * k


def test_spec_sampled_engine_stream_is_reproducible(setup):
    """End-to-end spec-sampled decoding: same seeds => identical streams,
    budgets honored, and per-request accept stats populated."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    motif = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    prompts = [np.tile(motif, 3) for _ in range(2)]

    def run():
        eng = _engine(cfg, params, n_slots=2, spec_k=3, draft_bits=8)
        reqs = [
            eng.submit(p, _sampled(70 + i, new_tokens=8),
                       PrecisionParams(w_bits=8, kv_bits=8))
            for i, p in enumerate(prompts)
        ]
        eng.run()
        return eng, reqs

    eng_a, reqs_a = run()
    eng_b, reqs_b = run()
    assert [r.out_tokens for r in reqs_a] == [r.out_tokens for r in reqs_b]
    assert all(len(r.out_tokens) == 8 for r in reqs_a)
    assert all(0 <= t < cfg.vocab for r in reqs_a for t in r.out_tokens)
    assert eng_a.stats.spec_rounds > 0
    # same-precision draft (W8 == W8 target): sampled drafts and target draw
    # from identical distributions, so rejection acceptance is ~1 — every
    # request's own counters must reflect it
    for r in reqs_a:
        assert r.spec_drafted > 0
        assert r.spec_accepted <= r.spec_drafted
    assert eng_a.stats.spec_accept_rate > 0.8
