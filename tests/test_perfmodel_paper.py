"""Perf-model vs the paper's own claims.

Two tiers:
  * HARD qualitative invariants (must hold for any sane calibration):
    dataflow ordering, precision monotonicity, SPEED > Ara, mixed >= both.
  * SOFT quantitative bands vs Table I / Fig. 3 / Fig. 4 (the analytical
    model is calibrated, not cycle-accurate — EXPERIMENTS.md reports exact
    relative errors; these tests pin generous bands so regressions surface).
"""
import pytest

from repro.core.isa import Dataflow
from repro.core.perfmodel import (
    AraModel,
    SpeedModel,
    evaluate_layer,
    evaluate_network,
    evaluate_network_ara,
    select_dataflow,
)
from repro.core.precision import Precision
from repro.models.cnn_zoo import BENCHMARK_NETWORKS, googlenet_layers

I16, I8, I4 = Precision.INT16, Precision.INT8, Precision.INT4
SM, AM = SpeedModel(), AraModel()


def test_mixed_never_worse_than_pure():
    for net, f in BENCHMARK_NETWORKS.items():
        for prec in (I16, I8, I4):
            r = {s: evaluate_network(f(), prec, s, SM)["gops"] for s in ("ff", "cf", "mixed")}
            assert r["mixed"] >= r["ff"] * 0.999, (net, prec, r)
            assert r["mixed"] >= r["cf"] * 0.999, (net, prec, r)


def test_precision_monotonicity():
    """Narrower precision never slows the network down (SPEED's raison d'etre)."""
    for f in BENCHMARK_NETWORKS.values():
        g16 = evaluate_network(f(), I16, "mixed", SM)["gops"]
        g8 = evaluate_network(f(), I8, "mixed", SM)["gops"]
        g4 = evaluate_network(f(), I4, "mixed", SM)["gops"]
        assert g4 > g8 > g16


def test_speed_beats_ara_everywhere():
    for f in BENCHMARK_NETWORKS.values():
        for prec in (I16, I8):
            s = evaluate_network(f(), prec, "mixed", SM)["area_eff"]
            a = evaluate_network_ara(f(), prec, AM)["area_eff"]
            assert s > a


def test_ara_has_no_4bit():
    with pytest.raises(ValueError):
        AM.evaluate(googlenet_layers()[0], I4)


def test_conv1x1_prefers_cf_at_16bit():
    """Paper Fig. 3: 'CF-only strategy is better suited for conv1x1'."""
    ones = [l for l in googlenet_layers() if l.k == 1]
    cf_wins = sum(select_dataflow(l, I16, SM) is Dataflow.CF for l in ones)
    assert cf_wins / len(ones) > 0.7, f"{cf_wins}/{len(ones)}"


def test_peak_bands_vs_table1():
    """Table I peaks within a generous band (exact errors in EXPERIMENTS.md)."""
    layers = [l for f in BENCHMARK_NETWORKS.values() for l in f()]

    def peak(prec):
        return max(
            max(SM.evaluate(l, prec, Dataflow.FF).gops, SM.evaluate(l, prec, Dataflow.CF).gops)
            for l in layers
        )

    assert 0.5 * 34.89 < peak(I16) < 2.0 * 34.89
    assert 0.5 * 93.65 < peak(I8) < 2.0 * 93.65
    assert 0.4 * 287.41 < peak(I4) < 2.5 * 287.41
    ara8 = max(AM.evaluate(l, I8).gops for l in layers)
    assert 0.4 * 22.95 < ara8 < 2.0 * 22.95


def test_fig4_direction():
    """SPEED/Ara average area-efficiency gap grows as precision narrows
    (Fig. 4: 2.77x @16b -> 6.39x @8b; 4-bit has no Ara counterpart)."""
    nets = [f() for f in BENCHMARK_NETWORKS.values()]

    def ratio(prec):
        s = sum(evaluate_network(ls, prec, "mixed", SM)["area_eff"] for ls in nets)
        a = sum(evaluate_network_ara(ls, prec, AM)["area_eff"] for ls in nets)
        return s / a

    assert ratio(I8) > ratio(I16) > 1.0


def test_layer_perf_fields():
    l = googlenet_layers()[3]
    p = evaluate_layer(l, I8, "mixed", SM)
    assert p.cycles > 0 and 0 < p.utilization < 1.0
    assert p.area_eff == pytest.approx(p.gops / SM.area_mm2)
    assert p.energy_eff == pytest.approx(p.gops / SM.power_w)
