"""Custom-instruction encodings: round-trip, field packing, decode rejection."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.isa import (
    OPCODE_CUSTOM1,
    OPCODE_OP_V,
    VSACFG,
    VSALD,
    VSAM,
    Dataflow,
    decode,
    disassemble,
    encode,
)
from repro.core.precision import Precision

PRECISIONS = [Precision.INT4, Precision.INT8, Precision.INT16]


@settings(max_examples=200, deadline=None)
@given(
    prec=st.sampled_from(PRECISIONS),
    df=st.sampled_from([Dataflow.FF, Dataflow.CF]),
    kh=st.integers(0, 7),
    clr=st.booleans(),
    th=st.integers(0, 31),
    rd=st.integers(0, 31),
)
def test_vsacfg_roundtrip(prec, df, kh, clr, th, rd):
    inst = VSACFG(precision=prec, dataflow=df, kernel_hint=kh, acc_clear=clr, tile_h=th, rd=rd)
    word = encode(inst)
    assert 0 <= word < (1 << 32)
    assert word & 0x7F == OPCODE_OP_V
    assert decode(word) == inst


@settings(max_examples=200, deadline=None)
@given(
    vd=st.integers(0, 31),
    rs1=st.integers(0, 31),
    ln=st.integers(0, 31),
    bc=st.booleans(),
)
def test_vsald_roundtrip(vd, rs1, ln, bc):
    inst = VSALD(vd=vd, rs1=rs1, length=ln, broadcast=bc)
    word = encode(inst)
    assert word & 0x7F == OPCODE_CUSTOM1
    assert decode(word) == inst


@settings(max_examples=200, deadline=None)
@given(acc=st.integers(0, 31), vs1=st.integers(0, 31), vs2=st.integers(0, 31))
def test_vsam_roundtrip(acc, vs1, vs2):
    inst = VSAM(acc=acc, vs1=vs1, vs2=vs2)
    assert decode(encode(inst)) == inst


def test_distinct_encodings():
    words = {
        encode(VSACFG()),
        encode(VSALD(vd=1, rs1=2)),
        encode(VSAM(acc=1, vs1=2, vs2=3)),
    }
    assert len(words) == 3


def test_decode_rejects_non_custom():
    with pytest.raises(ValueError):
        decode(0x00000013)  # addi x0, x0, 0
    with pytest.raises(ValueError):
        decode(1 << 33)


def test_field_overflow_rejected():
    with pytest.raises(ValueError):
        VSALD(vd=32, rs1=0).encode()
    with pytest.raises(ValueError):
        VSACFG(tile_h=32).encode()


def test_disassemble():
    assert "vsacfg" in disassemble(encode(VSACFG(precision=Precision.INT4)))
    assert "bcast" in disassemble(encode(VSALD(vd=1, rs1=2, broadcast=True)))
    assert "vsam" in disassemble(encode(VSAM(acc=16, vs1=0, vs2=8)))
