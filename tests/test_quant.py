"""Quantization substrate: packing round-trips, error bounds, QTensor."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.precision import Precision
from repro.quant import (
    QTensor,
    dequantize,
    fake_quantize,
    pack_int4,
    quantize,
    quantize_per_channel,
    unpack_int4,
)


@settings(max_examples=100, deadline=None)
@given(
    hnp.arrays(
        np.int8,
        hnp.array_shapes(min_dims=1, max_dims=3, min_side=2, max_side=16).filter(
            lambda s: s[-1] % 2 == 0
        ),
        elements=st.integers(-8, 7),
    )
)
def test_pack_unpack_roundtrip(arr):
    packed = pack_int4(jnp.asarray(arr))
    assert packed.shape[-1] == arr.shape[-1] // 2
    back = unpack_int4(packed)
    np.testing.assert_array_equal(np.asarray(back), arr)


@settings(max_examples=50, deadline=None)
@given(
    hnp.arrays(
        np.float32,
        st.tuples(st.integers(2, 8), st.integers(2, 32).map(lambda x: 2 * x)),
        elements=st.floats(-100, 100, width=32),
    ),
    st.sampled_from([Precision.INT4, Precision.INT8, Precision.INT16]),
)
def test_quantize_error_bound(arr, prec):
    x = jnp.asarray(arr)
    q = quantize(x, prec)
    deq = dequantize(q)
    # symmetric absmax quantization error <= scale/2 elementwise
    bound = float(q.scale.reshape(())) / 2 + 1e-6
    assert float(jnp.max(jnp.abs(deq - x))) <= bound


def test_per_channel_scales_beat_per_tensor():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 8)) * np.array([0.01, 1, 100, 0.1, 10, 1, 1, 1]))
    pt = dequantize(quantize(x, Precision.INT8))
    pc = dequantize(quantize_per_channel(x, Precision.INT8, channel_axis=-1))
    err_pt = float(jnp.mean(jnp.abs(pt - x)))
    err_pc = float(jnp.mean(jnp.abs(pc - x)))
    assert err_pc < err_pt


def test_int4_packed_payload_halves():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(16, 32)), jnp.float32)
    q4 = quantize(x, Precision.INT4)
    q8 = quantize(x, Precision.INT8)
    assert q4.packed and q4.data.shape == (16, 16)
    assert q4.logical_shape == (16, 32)
    assert q4.data.size == q8.data.size // 2


def test_qtensor_pytree():
    import jax

    x = jnp.ones((4, 8))
    q = quantize(x, Precision.INT8)
    leaves, treedef = jax.tree_util.tree_flatten(q)
    q2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(q2, QTensor) and q2.precision == q.precision


def test_fake_quantize_idempotent_on_grid():
    # values already on the quant grid survive exactly
    prec = Precision.INT8
    scale = 0.5
    x = jnp.asarray([-3.0, -0.5, 0.0, 1.5, 63.5])
    fq = fake_quantize(x, prec)
    fq2 = fake_quantize(fq, prec)
    np.testing.assert_allclose(np.asarray(fq), np.asarray(fq2), rtol=1e-6)
