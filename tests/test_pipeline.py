"""Pipeline parallelism over the pod axis: exactness vs sequential stages."""


def test_pipeline_matches_sequential(subproc):
    subproc(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply

        mesh = jax.make_mesh((4,), ("pod",))
        n_stages, n_micro, bm, d = 4, 8, 2, 16
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(n_stages, d, d)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.normal(size=(n_micro, bm, d)), jnp.float32)

        def stage_fn(p, mb):
            return jnp.tanh(mb @ p)

        got = pipeline_apply(stage_fn, w, x, mesh, axis="pod")

        ref = x
        for s in range(n_stages):
            ref = jax.vmap(lambda mb: stage_fn(w[s], mb))(ref)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5, rtol=1e-5)
        print("pipeline OK")
        """,
        n_devices=4,
    )


def test_pipeline_single_stage_degenerate(subproc):
    subproc(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply

        mesh = jax.make_mesh((1,), ("pod",))
        w = jnp.ones((1, 4, 4), jnp.float32)
        x = jnp.ones((3, 2, 4), jnp.float32)
        got = pipeline_apply(lambda p, mb: mb @ p, w, x, mesh, axis="pod")
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w[0]), atol=1e-6)
        print("degenerate pipeline OK")
        """,
        n_devices=1,
    )
