"""Pallas mpmm kernel vs the pure-jnp oracle: shape/dtype/precision/dataflow
sweep in interpret mode (kernel body executes on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quant.pack import pack_int4

RNG = np.random.default_rng(0)


def _float_case(m, k, n):
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(k, n)), jnp.float32)
    return x, w


@pytest.mark.parametrize("w_bits", [4, 8])
@pytest.mark.parametrize("dataflow", ["cf", "ff"])
@pytest.mark.parametrize(
    "m,k,n",
    [(8, 128, 128), (96, 384, 160), (1, 256, 512), (130, 520, 130)],
)
def test_dequant_sweep(w_bits, dataflow, m, k, n):
    x, w = _float_case(m, k, n)
    wd, ws = ops.pack_weights(w, w_bits)
    got = ops.mpmm(x, wd, ws, w_bits=w_bits, mode="dequant", dataflow=dataflow)
    exp = ref.mpmm_ref(x, wd, ws, w_bits=w_bits, mode="dequant")
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("w_bits,x_bits", [(16, 16), (8, 8), (4, 8), (8, 16), (16, 8)])
@pytest.mark.parametrize("dataflow", ["cf", "ff"])
def test_int_mode_bit_exact(w_bits, x_bits, dataflow):
    m, k, n = 32, 256, 128
    xlim = 2 ** (x_bits - 1) - 1
    x = jnp.asarray(
        RNG.integers(-xlim, xlim, (m, k)), jnp.int16 if x_bits == 16 else jnp.int8
    )
    wlim = 7 if w_bits == 4 else 2 ** (w_bits - 1) - 1
    wq = RNG.integers(-wlim - 1, wlim + 1, (k, n))
    wq = jnp.asarray(wq, jnp.int16 if w_bits == 16 else jnp.int8)
    wd = pack_int4(wq.astype(jnp.int8), axis=0) if w_bits == 4 else wq
    ws = jnp.ones((1, n), jnp.float32)
    got_scaled = ops.mpmm(x, wd, ws, w_bits=w_bits, x_bits=x_bits, mode="int", dataflow=dataflow)
    exp = ref.mpmm_ref(x, wd, ws, w_bits=w_bits, mode="int")
    np.testing.assert_array_equal(
        np.asarray(got_scaled), np.asarray(exp).astype(np.float32)
    )


def test_int_wraparound_semantics():
    """int32 accumulator wraparound matches the 32-bit SAU semantics."""
    m, k, n = 8, 256, 128
    x = jnp.full((m, k), 32767, jnp.int16)
    wq = jnp.full((k, n), 32767, jnp.int16)
    ws = jnp.ones((1, n), jnp.float32)
    got = ops.mpmm(x, wq, ws, w_bits=16, x_bits=16, mode="int")
    exp = ref.mpmm_ref(x, wq, ws, w_bits=16, mode="int").astype(np.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_batched_leading_dims():
    x = jnp.asarray(RNG.normal(size=(2, 3, 256)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(256, 128)), jnp.float32)
    wd, ws = ops.pack_weights(w, 8)
    got = ops.mpmm(x, wd, ws, w_bits=8)
    assert got.shape == (2, 3, 128)
    exp = ref.mpmm_ref(x.reshape(-1, 256), wd, ws, w_bits=8, mode="dequant").reshape(2, 3, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-3)


def test_w16_dequant_rejected():
    x, w = _float_case(8, 128, 128)
    wd, ws = jnp.zeros((128, 128), jnp.int16), jnp.ones((1, 128), jnp.float32)
    with pytest.raises(ValueError):
        ops.mpmm(x, wd, ws, w_bits=16, mode="dequant")


def test_auto_dataflow_dispatch():
    x, w = _float_case(64, 256, 128)
    wd, ws = ops.pack_weights(w, 8)
    got = ops.mpmm(x, wd, ws, w_bits=8, dataflow="auto")
    exp = ref.mpmm_ref(x, wd, ws, w_bits=8, mode="dequant")
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-3)


@pytest.mark.parametrize("w_bits", [4, 8])
def test_ff_accumulates_partials_in_f32_at_large_k(w_bits):
    """Regression: the FF kernel used to accumulate cross-K-stage partial
    sums in the bf16 *output* dtype (and the wrapper applied w_scale in
    bf16), diverging from CF's f32 VMEM accumulator as K grows.  Both
    dataflows now run the same f32 stage-sum in the same order, so at
    K = 4096 (8 stages) they must agree bit-for-bit and sit within one bf16
    rounding of the f32 oracle."""
    m, k, n = 8, 4096, 128
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.bfloat16)
    w = jnp.asarray(RNG.normal(size=(k, n)), jnp.float32)
    wd, ws = ops.pack_weights(w, w_bits)
    ff = ops.mpmm(x, wd, ws, w_bits=w_bits, mode="dequant", dataflow="ff")
    cf = ops.mpmm(x, wd, ws, w_bits=w_bits, mode="dequant", dataflow="cf")
    assert ff.dtype == cf.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(ff, np.float32), np.asarray(cf, np.float32)
    )
    exp = ref.mpmm_ref(x, wd, ws, w_bits=w_bits, mode="dequant")
    np.testing.assert_allclose(
        np.asarray(ff, np.float32), np.asarray(exp, np.float32),
        rtol=1e-2, atol=1e-2,
    )


def test_xla_backend_matches_pallas():
    x, w = _float_case(32, 256, 128)
    wd, ws = ops.pack_weights(w, 4)
    a = ops.mpmm(x, wd, ws, w_bits=4, backend="pallas")
    b = ops.mpmm(x, wd, ws, w_bits=4, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)
