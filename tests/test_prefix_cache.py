"""Prefix-cache + chunked-prefill subsystem: refcount/eviction invariants,
copy-on-write forking, preempt→evict→readmit equivalence, chunked-vs-one-shot
prefill equality across kv precisions, and cross-precision isolation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import (
    PagedKVCache,
    PrecisionParams,
    PrefixCache,
    SamplingParams,
    ServeEngine,
    block_hashes,
)


def _cfg(**kw):
    base = dataclasses.replace(
        get_config("llama3.2-3b").reduced(),
        n_layers=2, d_model=64, d_ff=128, vocab=256, n_heads=4, n_kv_heads=2,
        head_dim=16, serve_kv_bits=8,
    )
    return dataclasses.replace(base, **kw)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _pool(cfg, num_pages=8, page_size=4, kv_bits=8):
    return PagedKVCache(cfg, num_pages=num_pages, page_size=page_size, kv_bits=kv_bits)


# ---------------------------------------------------- hash-chain + bookkeeping
def test_block_hash_chain_prefix_property():
    a = np.arange(32, dtype=np.int32)
    b = np.concatenate([np.arange(16, dtype=np.int32), 99 + np.arange(16, dtype=np.int32)])
    ha, hb = block_hashes(a, 8), block_hashes(b, 8)
    assert len(ha) == len(hb) == 4
    assert ha[:2] == hb[:2]  # shared 16-token prefix
    assert ha[2:] != hb[2:]  # divergence poisons every later block
    assert block_hashes(a, 8, ("w", 4)) != ha  # salt separates weight precisions
    assert block_hashes(a[:7], 8) == []  # partial blocks are not hashable


def test_refcount_sharing_and_release_to_lru():
    cfg = _cfg()
    pool = _pool(cfg)
    pc = PrefixCache(pool)
    h = block_hashes(np.arange(8, dtype=np.int32), 4)
    t0 = pool.allocate(0, 2)
    pc.register(h, t0)
    # a second request adopts both pages: refcount 2, still registered
    pool.allocate(1, 3, prefix_pages=tuple(t0))
    assert pool.refcount(t0[0]) == 2
    pool.free(0)
    assert pool.refcount(t0[0]) == 1  # rid 1 still holds them
    assert pc.num_retained == 0 and pool.num_free == 5
    pool.free(1)
    # last ref dropped: registered pages retained in LRU, the fresh page freed
    assert pc.num_retained == 2 and pool.num_free == 6
    assert pool.num_allocatable == 8
    # match serves the retained chain; adopting revives it out of the LRU
    assert pc.match(h) == t0
    pool.allocate(2, 2, prefix_pages=tuple(t0))
    pc.acquire_note(t0)
    assert pc.num_retained == 0 and pool.refcount(t0[0]) == 1


def test_lru_eviction_order_and_liveness():
    cfg = _cfg()
    pool = _pool(cfg, num_pages=4)
    pc = PrefixCache(pool)
    ha = block_hashes(np.arange(4, dtype=np.int32), 4)
    hb = block_hashes(100 + np.arange(4, dtype=np.int32), 4)
    pa = pool.allocate(0, 1)
    pc.register(ha, pa)
    pb = pool.allocate(1, 1)
    pc.register(hb, pb)
    pool.free(0)  # retained first -> LRU victim
    pool.free(1)
    assert pc.num_retained == 2 and pool.num_free == 2
    # allocating 3 pages reclaims the least-recently-used entry (ha) only
    pool.allocate(2, 3)
    assert pc.match(ha) == [] and pc.match(hb) == pb
    assert pc.stats.evictions == 1
    # a *live* registered page is never evicted: hb's page is re-adopted
    pool.allocate(3, 1, prefix_pages=tuple(pb))
    pc.acquire_note(pb)
    pool.free(2)
    pool.allocate(4, 3)  # needs every free page; must not touch live pb
    assert pc.match(hb) == pb
    assert pool.refcount(pb[0]) == 1


def test_copy_on_write_fork_leaves_original_intact():
    cfg = _cfg()
    pool = _pool(cfg)
    rng = np.random.default_rng(0)
    pool.allocate(0, 2)
    L, ps, hkv, hd = cfg.n_layers, 4, cfg.n_kv_heads, cfg.hd
    kq = rng.integers(-127, 128, (L, 8, hkv, hd)).astype(np.int8)
    ks = (rng.random((L, 8, hkv, 1)) * 0.1).astype(np.float32)
    pool.write_prompt(0, jnp.asarray(kq), jnp.asarray(kq), jnp.asarray(ks), jnp.asarray(ks))
    orig = pool.table(0)
    # second request adopts both pages then forks the last one (divergence)
    pool.allocate(1, 2, prefix_pages=tuple(orig))
    new = pool.fork_page(1, 1)
    assert new not in orig and pool.table(1) == [orig[0], new]
    assert pool.refcount(orig[1]) == 1  # rid 0's reference only
    # the fork is payload-identical until someone writes it
    np.testing.assert_array_equal(
        np.asarray(pool.k[:, new]), np.asarray(pool.k[:, orig[1]])
    )
    # writing the fork leaves the original untouched
    tok = jnp.full((L, 1, hkv, hd), 7, jnp.int8)
    sc = jnp.ones((L, 1, hkv, 1), jnp.float32)
    pool.write_token([1], np.array([7]), (tok, tok, sc, sc))
    np.testing.assert_array_equal(np.asarray(pool.k[:, orig[1], 3]), kq[:, 7])
    np.testing.assert_array_equal(
        np.asarray(pool.k[:, new, 3]), np.full((L, hkv, hd), 7, np.int8)
    )


# ------------------------------------------------------- engine-level reuse
def _run_engine(cfg, params, prompts, new_tokens=4, prefill_chunk=32, **submit_kw):
    eng = ServeEngine(
        cfg, params, max_slots=len(prompts), num_pages=64, page_size=4,
        prefill_chunk=prefill_chunk,
    )
    sampling = SamplingParams(max_new_tokens=new_tokens)
    precision = PrecisionParams(**submit_kw)
    reqs = [eng.submit(p, sampling, precision) for p in prompts]
    eng.run()
    return eng, reqs


@pytest.mark.parametrize("kv_bits", [4, 8, 16])
def test_chunked_equals_one_shot_prefill(setup, kv_bits):
    """Chunked prefill (chunk < prompt) must produce the same greedy tokens
    as a one-shot prefill (chunk >= prompt), for every kv precision."""
    cfg, params = setup
    w_bits = 16 if kv_bits == 16 else 8
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 19).astype(np.int32) for _ in range(2)]
    _, chunked = _run_engine(
        cfg, params, prompts, prefill_chunk=4, w_bits=w_bits, kv_bits=kv_bits
    )
    _, oneshot = _run_engine(
        cfg, params, prompts, prefill_chunk=64, w_bits=w_bits, kv_bits=kv_bits
    )
    assert [r.out_tokens for r in chunked] == [r.out_tokens for r in oneshot]


def test_chunked_prefill_matches_manual_decode_loop(setup):
    """Cold chunked prefill through the paged pool == the dense
    prefill + decode_step reference loop (greedy, bf16)."""
    cfg, params = setup
    cfg16 = dataclasses.replace(cfg, serve_kv_bits=16)
    prompt = np.arange(1, 14, dtype=np.int32)
    _, (req,) = _run_engine(
        cfg16, params, [prompt], new_tokens=4, prefill_chunk=4,
        w_bits=16, kv_bits=16,
    )
    logits, cache = T.prefill(params, {"tokens": jnp.asarray(prompt)[None]}, cfg16, 64)
    manual = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        manual.append(int(tok[0, 0]))
        logits, cache = T.decode_step(params, tok, cache, cfg16)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert req.out_tokens == manual


@pytest.mark.parametrize("kv_bits", [4, 8, 16])
def test_warm_prefix_equals_cold_run(setup, kv_bits):
    """A warm-cache request (prefix pages adopted, only the suffix computed)
    must produce token-for-token the same greedy output as the identical
    request on a cold engine."""
    cfg, params = setup
    w_bits = 16 if kv_bits == 16 else 8
    rng = np.random.default_rng(6)
    sys_prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab, 6).astype(np.int32) for _ in range(2)]
    prompts = [np.concatenate([sys_prompt, t]) for t in tails]

    eng = ServeEngine(cfg, params, max_slots=2, num_pages=64, page_size=4,
                      prefill_chunk=8)
    a = eng.submit(prompts[0], SamplingParams(max_new_tokens=5), PrecisionParams(w_bits=w_bits, kv_bits=kv_bits))
    eng.run()
    b = eng.submit(prompts[1], SamplingParams(max_new_tokens=5), PrecisionParams(w_bits=w_bits, kv_bits=kv_bits))
    eng.run()
    assert eng.stats.prefix_hit_tokens >= 16  # b adopted the shared prefix

    for i, warm in enumerate((a, b)):
        cold_eng = ServeEngine(cfg, params, max_slots=1, num_pages=64,
                               page_size=4, prefill_chunk=8,
                               enable_prefix_cache=False)
        cold = cold_eng.submit(prompts[i], SamplingParams(max_new_tokens=5), PrecisionParams(w_bits=w_bits, kv_bits=kv_bits))
        cold_eng.run()
        assert warm.out_tokens == cold.out_tokens, f"request {i} (kv{kv_bits})"


def test_full_prompt_hit_forks_divergence_page(setup):
    """Identical prompt twice, prompt length an exact page multiple: the
    second request hits every block, is capped at plen-1, CoW-forks the last
    page, and still produces identical tokens."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)  # 4 pages of 4
    eng = ServeEngine(cfg, params, max_slots=1, num_pages=32, page_size=4,
                      prefill_chunk=8)
    a = eng.submit(prompt, SamplingParams(max_new_tokens=4), PrecisionParams(w_bits=8, kv_bits=8))
    eng.run()
    b = eng.submit(prompt, SamplingParams(max_new_tokens=4), PrecisionParams(w_bits=8, kv_bits=8))
    eng.run()
    pc = eng.prefix_cache_for(8)
    assert pc.stats.forks >= 1
    assert a.out_tokens == b.out_tokens
    # 15 of 16 prompt tokens served from cache on the second admission
    assert eng.stats.prefix_hit_tokens == 15


def test_full_pool_degrades_hit_instead_of_stalling(setup):
    """A capped (mid-page) hit needs one transient fork page; when the pool
    is entirely the request's own cached chain, admission must degrade to
    the floored no-fork hit instead of failing forever."""
    from repro.serve import ServeRequest

    cfg, params = setup
    eng = ServeEngine(cfg, params, max_slots=2, num_pages=2, page_size=4,
                      prefill_chunk=16)
    cache = eng.cache_for(8)
    pc = eng.prefix_cache_for(8)
    prompt = np.arange(8, dtype=np.int32)  # exactly 2 blocks
    hashes = block_hashes(prompt, 4, ("w", 8))
    pages = cache.allocate(0, 2)
    pc.register(hashes, pages)
    cache.free(0)  # whole pool = this chain, retained, zero free pages
    req = ServeRequest(rid=1, prompt=prompt, max_new_tokens=1,
                       w_bits=8, kv_bits=8)
    # capped hit (7 tokens) would need 2 shared + 1 fork page = impossible;
    # the cascade lands on the floored 1-block hit, evicting the tail block
    assert eng._try_admit(req)
    assert req.cache_len == 4
    assert cache.table(1)[0] == pages[0]  # head block adopted
    assert pc.match(hashes) == pages[:1]  # tail block was evicted


def test_preempt_evict_readmit_matches_uncached_run(setup):
    """Preemption releases pages into the prefix cache; readmission resumes
    from the still-cached blocks (recompute only what was evicted) and the
    final tokens equal an engine with caching disabled."""
    cfg, params = setup
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab, 10).astype(np.int32) for _ in range(3)]

    def run(enable):
        eng = ServeEngine(cfg, params, max_slots=3, num_pages=10, page_size=4,
                          prefill_chunk=16, enable_prefix_cache=enable)
        reqs = [eng.submit(p, SamplingParams(max_new_tokens=8), PrecisionParams(w_bits=8, kv_bits=8)) for p in prompts]
        eng.run()
        return eng, reqs

    warm_eng, warm = run(True)
    cold_eng, cold = run(False)
    assert warm_eng.stats.preemptions > 0 and cold_eng.stats.preemptions > 0
    assert all(len(r.out_tokens) == 8 for r in warm)
    assert [r.out_tokens for r in warm] == [r.out_tokens for r in cold]


def test_preempt_resumes_from_cached_pages(setup):
    """A preempted request's materialized blocks are released *into* the
    prefix cache; readmission adopts the surviving chain (prompt AND
    generated-token blocks) instead of re-prefilling from scratch, and the
    continuation equals an undisturbed run."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)

    eng = ServeEngine(cfg, params, max_slots=1, num_pages=32, page_size=4,
                      prefill_chunk=16)
    req = eng.submit(prompt, SamplingParams(max_new_tokens=8), PrecisionParams(w_bits=8, kv_bits=8))
    for _ in range(5):  # prefill + a few decode steps
        eng.step()
    assert len(req.out_tokens) >= 4
    hits_before = eng.stats.prefix_hit_tokens
    eng._preempt(req)  # deterministic mid-decode eviction
    eng.run()
    assert req.done and len(req.out_tokens) == 8 and req.preemptions == 1
    # readmission hit the feed chain (prompt + generated tokens, sans the
    # capped divergence token) rather than recomputing it
    assert eng.stats.prefix_hit_tokens - hits_before >= 12

    undisturbed = ServeEngine(cfg, params, max_slots=1, num_pages=32,
                              page_size=4, prefill_chunk=16,
                              enable_prefix_cache=False)
    ref = undisturbed.submit(prompt, SamplingParams(max_new_tokens=8), PrecisionParams(w_bits=8, kv_bits=8))
    undisturbed.run()
    assert req.out_tokens == ref.out_tokens


def test_cross_precision_isolation(setup):
    """A bf16 request must not hit int8 prefix pages (separate pools), and a
    W4 request must not hit W8-written pages (hash-chain salt)."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    eng = ServeEngine(cfg, params, max_slots=1, num_pages=64, page_size=4,
                      prefill_chunk=16)
    eng.submit(prompt, SamplingParams(max_new_tokens=2), PrecisionParams(w_bits=8, kv_bits=8))
    eng.run()
    hits0 = eng.stats.prefix_hit_tokens
    # same tokens, bf16 KV: different pool, no hit possible
    eng.submit(prompt, SamplingParams(max_new_tokens=2), PrecisionParams(w_bits=16, kv_bits=16))
    eng.run()
    assert eng.stats.prefix_hit_tokens == hits0
    # same tokens, same kv pool, different weight precision: salt separates
    eng.submit(prompt, SamplingParams(max_new_tokens=2), PrecisionParams(w_bits=4, kv_bits=8))
    eng.run()
    assert eng.stats.prefix_hit_tokens == hits0
    # and the same (w, kv) choice *does* hit
    eng.submit(prompt, SamplingParams(max_new_tokens=2), PrecisionParams(w_bits=8, kv_bits=8))
    eng.run()
    assert eng.stats.prefix_hit_tokens > hits0


def test_interleaved_prefill_does_not_stall_decode(setup):
    """A long prompt admitted mid-stream prefills in chunks while the running
    request keeps decoding (no full-prompt stall)."""
    cfg, params = setup
    rng = np.random.default_rng(10)
    eng = ServeEngine(cfg, params, max_slots=2, num_pages=64, page_size=4,
                      prefill_chunk=4)
    a = eng.submit(rng.integers(0, cfg.vocab, 4).astype(np.int32), SamplingParams(max_new_tokens=12), PrecisionParams(w_bits=8))
    eng.step()
    before = len(a.out_tokens)
    b = eng.submit(rng.integers(0, cfg.vocab, 24).astype(np.int32), SamplingParams(max_new_tokens=2), PrecisionParams(w_bits=8))
    eng.step()  # b prefills its first chunk only...
    assert 0 < b.cache_len < 24
    assert len(a.out_tokens) > before  # ...while a decoded in the same step
    eng.run()
    assert a.done and b.done and len(b.out_tokens) == 2
