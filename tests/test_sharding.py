"""Sharding rules: pattern matching, divisibility validation, tree coverage."""
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import param_spec


def test_param_spec_rules():
    # 2-D weight sharding: TP over "model" + FSDP over "data"
    assert param_spec("embed", 2) == P("model", "data")
    assert param_spec("unembed", 2) == P("data", "model")
    assert param_spec("blocks/wq", 3) == P(None, "data", "model")  # stacked
    assert param_spec("blocks/wo", 3) == P(None, "model", "data")
    assert param_spec("blocks/moe/wg", 4) == P(None, "model", "data", None)
    assert param_spec("blocks/mlp/wd", 3) == P(None, "model", "data")
    assert param_spec("blocks/norm1", 2) == P(None, None)
    assert param_spec("final_norm", 1) == P(None)


def test_param_spec_fallback_candidates(subproc):
    """mixtral-style: 8 experts < 16 model shards -> the fallback candidate
    shards the matrix dims instead of replicating 140 GB of experts."""
    subproc(
        """
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import param_spec

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        # E=8 divisible -> experts sharded
        got = param_spec("blocks/moe/wg", 4, (56, 8, 6144, 16384), mesh)
        assert got == P(None, "model", "data", None), got
        # E=3 NOT divisible -> fallback shards D(data) x F(model)
        got2 = param_spec("blocks/moe/wg", 4, (56, 3, 6144, 16384), mesh)
        assert got2 == P(None, None, "data", "model"), got2
        print("fallback OK")
        """,
        n_devices=4,
    )


def test_validate_spec_drops_nondivisible(subproc):
    subproc(
        """
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import validate_spec

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        # 7 not divisible by 2 -> dropped; 8 divisible -> kept
        got = validate_spec(P("data", "model"), (7, 8), mesh)
        assert got == P(None, "model"), got
        got2 = validate_spec(P(("data", "model"), None), (8, 3), mesh)
        assert got2 == P(("data", "model"), None), got2
        got3 = validate_spec(P(("data", "model"), None), (6, 3), mesh)
        assert got3 == P(None, None), got3
        print("validate OK")
        """,
        n_devices=4,
    )


def test_tree_shardings_cover_reduced_arch(subproc):
    """Every parameter of every family gets a consistent sharding on a real
    mesh, and the big 2-D weights are actually model-sharded."""
    subproc(
        """
        import jax
        from functools import partial
        from repro.configs import get_config
        from repro.distributed.sharding import tree_shardings
        from repro.models import transformer as T

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        for name in ("yi-9b", "kimi-k2-1t-a32b", "zamba2-7b", "mamba2-130m"):
            cfg = get_config(name).reduced()
            shapes = jax.eval_shape(partial(T.init_params, cfg), jax.random.PRNGKey(0))
            sh = tree_shardings(shapes, mesh)
            flat = jax.tree_util.tree_leaves(sh)
            assert len(flat) == len(jax.tree_util.tree_leaves(shapes))
            # embed is vocab-sharded (padded vocab divisible by 256)
            assert "model" in str(sh["embed"].spec)
        print("tree shardings OK")
        """,
        n_devices=4,
    )
