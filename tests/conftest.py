"""Shared test utilities.

NOTE: XLA_FLAGS --xla_force_host_platform_device_count is deliberately NOT
set here — smoke tests and benches must see 1 device.  Multi-device tests
spawn subprocesses (see _subproc) that set the flag before importing jax.
"""
import os
import subprocess
import sys
import textwrap

import pytest


def run_subprocess_jax(code: str, n_devices: int = 8, timeout: int = 600):
    """Runs `code` in a fresh python with n_devices fake host devices.
    Returns CompletedProcess; asserts on failure with full output."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess_jax
