"""Optimizers: convergence on a quadratic, 8-bit fidelity, adafactor memory."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adafactor, adamw, adamw8bit


def _quadratic_problem(seed=0, d=64):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.normal(size=(d, d)), jnp.float32)
    params = {"w": jnp.zeros((d, d), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2) + jnp.mean((p["b"] - 1.0) ** 2)

    return params, loss


@pytest.mark.parametrize("make", [adamw, adamw8bit, adafactor])
def test_loss_decreases(make):
    params, loss = _quadratic_problem()
    init, update = make()
    state = init(params)
    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        upd, state = update(grads, state, params, lr=0.05)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(loss(params)) < 0.2 * l0


def test_adamw8bit_tracks_adamw():
    params, loss = _quadratic_problem(seed=1)
    i8, u8 = adamw8bit(wd=0.0)
    i32, u32 = adamw(wd=0.0)
    p8, s8 = dict(params), i8(params)
    p32, s32 = dict(params), i32(params)
    for _ in range(30):
        g8 = jax.grad(loss)(p8)
        g32 = jax.grad(loss)(p32)
        up8, s8 = u8(g8, s8, p8, lr=0.05)
        up32, s32 = u32(g32, s32, p32, lr=0.05)
        p8 = jax.tree.map(lambda p, u: p + u, p8, up8)
        p32 = jax.tree.map(lambda p, u: p + u, p32, up32)
    # trajectories agree to quantization tolerance
    d = float(jnp.max(jnp.abs(p8["w"] - p32["w"])))
    assert d < 0.15, d
    assert float(loss(p8)) < 0.5 * float(loss(params))


def test_state_memory_regimes():
    params = {"w": jnp.zeros((256, 512), jnp.float32)}

    def state_bytes(init):
        st = init(params)
        return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(st))

    b_adam = state_bytes(adamw()[0])
    b_8bit = state_bytes(adamw8bit()[0])
    b_fact = state_bytes(adafactor()[0])
    n = 256 * 512
    assert b_adam >= 8 * n  # fp32 m+v
    assert b_8bit < 0.35 * b_adam  # int8 payload + block scales
    assert b_fact < 0.02 * b_adam  # rows+cols only


def test_adafactor_factored_shapes():
    init, _ = adafactor()
    st = init({"w": jnp.zeros((16, 32)), "v": jnp.zeros((8,))})
    assert st.inner["w"]["r"].shape == (16,)
    assert st.inner["w"]["c"].shape == (32,)
    assert st.inner["v"]["v"].shape == (8,)
