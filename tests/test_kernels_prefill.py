"""Paged chunk-prefill kernel vs oracle: page-table indirection, quantized
pools (int8/int4/bf16), ragged ctx/q lengths, causal self-chunk masking,
bucket-padding rows, sliding windows, and decode-kernel consistency (a chunk
of one token == the paged decode kernel)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quant.pack import pack_int4

RNG = np.random.default_rng(7)


def _case(b, n_layers, n_pages, ps, w, c, hkv, groups, d, kv_bits):
    """Random pool + shuffled page tables + one query chunk per row."""
    h = hkv * groups
    q = jnp.asarray(RNG.normal(size=(b, c, h, d)), jnp.float32)
    if kv_bits < 16:
        lim = 8 if kv_bits == 4 else 128
        kp = RNG.integers(-lim, lim, (n_layers, n_pages, ps, hkv, d)).astype(np.int8)
        vp = RNG.integers(-lim, lim, (n_layers, n_pages, ps, hkv, d)).astype(np.int8)
        ks = (RNG.random((n_layers, n_pages, ps, hkv, 1)) * 0.1).astype(np.float32)
        vs = (RNG.random((n_layers, n_pages, ps, hkv, 1)) * 0.1).astype(np.float32)
        ck = RNG.integers(-lim, lim, (b, c, hkv, d)).astype(np.int8)
        cv = RNG.integers(-lim, lim, (b, c, hkv, d)).astype(np.int8)
        cks = (RNG.random((b, c, hkv, 1)) * 0.1).astype(np.float32)
        cvs = (RNG.random((b, c, hkv, 1)) * 0.1).astype(np.float32)
    else:
        kp = RNG.normal(size=(n_layers, n_pages, ps, hkv, d)).astype(np.float32)
        vp = RNG.normal(size=(n_layers, n_pages, ps, hkv, d)).astype(np.float32)
        ks = vs = cks = cvs = None
        ck = RNG.normal(size=(b, c, hkv, d)).astype(np.float32)
        cv = RNG.normal(size=(b, c, hkv, d)).astype(np.float32)
    tables = RNG.permutation(n_pages)[: b * w].reshape(b, w).astype(np.int32)
    J = lambda x: None if x is None else jnp.asarray(x)
    return (
        q, J(kp), J(vp), J(ks), J(vs), jnp.asarray(tables),
        J(ck), J(cv), J(cks), J(cvs),
    )


def _packed(x, kv_bits):
    if x is None or kv_bits != 4:
        return x
    return pack_int4(x, axis=-1)


def _prefill(case, ctx, qlen, layer, kv_bits, backend, window=None, interpret=None):
    q, kp, vp, ks, vs, tables, ck, cv, cks, cvs = case
    return ops.paged_mqa_prefill(
        q, _packed(kp, kv_bits), _packed(vp, kv_bits), ks, vs, tables,
        ctx, qlen, layer, _packed(ck, kv_bits), _packed(cv, kv_bits), cks, cvs,
        kv_bits=kv_bits, window=window, backend=backend, interpret=interpret,
    )


def _oracle(case, ctx, qlen, layer, d, window=None):
    q, kp, vp, ks, vs, tables, ck, cv, cks, cvs = case
    return ref.paged_mqa_prefill_ref(
        q, kp, vp, ks, vs, tables, ctx, qlen, layer, ck, cv, cks, cvs,
        sm_scale=1.0 / np.sqrt(d), window=window,
    )


def _rows(got, exp, qlen):
    """Compare only valid chunk rows (padding rows are unspecified)."""
    for row in range(got.shape[0]):
        n = int(qlen[row])
        np.testing.assert_allclose(
            np.asarray(got)[row, :n], np.asarray(exp)[row, :n],
            atol=3e-3, rtol=3e-3,
        )


@pytest.mark.parametrize("kv_bits", [8, 4, 16])
@pytest.mark.parametrize(
    "b,hkv,groups,d,ps,w,c",
    [
        (2, 2, 4, 64, 8, 4, 8),
        (3, 1, 8, 32, 16, 3, 5),  # MQA, non-pow2 batch/width/chunk
        (2, 4, 1, 64, 4, 5, 4),  # MHA
    ],
)
def test_prefill_matches_oracle(kv_bits, b, hkv, groups, d, ps, w, c):
    n_layers, n_pages = 2, b * w
    case = _case(b, n_layers, n_pages, ps, w, c, hkv, groups, d, kv_bits)
    # ragged ctx: full table, page-boundary, cold (0 cached tokens)
    ctx = jnp.asarray([w * ps, ps, 0][:b], jnp.int32)
    qlen = jnp.asarray([c, c - 1, c][:b], jnp.int32)
    for layer in range(n_layers):
        exp = _oracle(case, ctx, qlen, layer, d)
        for backend, interp in (("xla", None), ("pallas", True)):
            got = _prefill(case, ctx, qlen, layer, kv_bits, backend, interpret=interp)
            _rows(got, exp, qlen)


def test_cold_chunk_is_pure_causal_self_attention():
    """ctx == 0 everywhere: the kernel must equal plain causal attention over
    the chunk and read nothing from the (poisoned) pool."""
    b, hkv, groups, d, ps, w, c = 2, 2, 2, 32, 8, 4, 6
    case = _case(b, 1, b * w, ps, w, c, hkv, groups, d, 8)
    q, kp, vp, ks, vs, tables, ck, cv, cks, cvs = case
    case = (q, kp.at[:].set(127), vp.at[:].set(127), ks, vs, tables, ck, cv, cks, cvs)
    ctx = jnp.zeros((b,), jnp.int32)
    qlen = jnp.full((b,), c, jnp.int32)
    exp = _oracle(case, ctx, qlen, 0, d)
    # and against flash attention on the dequantized chunk
    from repro.models.attention import flash_attention

    ckf = jnp.repeat(ck * cks, groups, axis=2).astype(jnp.float32)
    cvf = jnp.repeat(cv * cvs, groups, axis=2).astype(jnp.float32)
    flash = flash_attention(q, ck * cks, cv * cvs, causal=True)
    np.testing.assert_allclose(np.asarray(exp), np.asarray(flash), atol=3e-3, rtol=3e-3)
    for backend, interp in (("xla", None), ("pallas", True)):
        got = _prefill(case, ctx, qlen, 0, 8, backend, interpret=interp)
        _rows(got, exp, qlen)


def test_window_masking_matches_oracle():
    b, hkv, groups, d, ps, w, c = 2, 2, 2, 32, 8, 4, 8
    case = _case(b, 1, b * w, ps, w, c, hkv, groups, d, 8)
    ctx = jnp.asarray([w * ps - 3, 2 * ps], jnp.int32)
    qlen = jnp.asarray([c, c - 2], jnp.int32)
    for window in (3, ps, 2 * ps + 5):
        exp = _oracle(case, ctx, qlen, 0, d, window=window)
        for backend, interp in (("xla", None), ("pallas", True)):
            got = _prefill(
                case, ctx, qlen, 0, 8, backend, window=window, interpret=interp
            )
            _rows(got, exp, qlen)


def test_single_token_chunk_matches_decode_kernel():
    """A chunk of one token must reproduce the paged *decode* kernel (whose
    fused new-token term is the c == 1 special case of the self-chunk)."""
    b, hkv, groups, d, ps, w = 2, 2, 3, 32, 8, 3
    case = _case(b, 1, b * w, ps, w, 1, hkv, groups, d, 8)
    q, kp, vp, ks, vs, tables, ck, cv, cks, cvs = case
    ctx = jnp.asarray([2 * ps + 3, 5], jnp.int32)
    qlen = jnp.ones((b,), jnp.int32)
    got = _prefill(case, ctx, qlen, 0, 8, "xla")
    dec = ops.paged_mqa_decode(
        q[:, 0], kp, vp, ks, vs, tables, ctx, 0,
        ck[:, 0], cv[:, 0], cks[:, 0], cvs[:, 0], kv_bits=8, backend="xla",
    )
    np.testing.assert_allclose(
        np.asarray(got[:, 0]), np.asarray(dec), atol=3e-3, rtol=3e-3
    )


def test_stale_pool_entries_beyond_ctx_are_dead():
    """Corrupting pool positions at/past each row's ctx must not change any
    valid output (clamped slots may be fetched, never used)."""
    b, hkv, groups, d, ps, w, c = 2, 2, 2, 32, 8, 4, 4
    case = _case(b, 1, b * w, ps, w, c, hkv, groups, d, 8)
    q, kp, vp, ks, vs, tables, ck, cv, cks, cvs = case
    ctx = jnp.asarray([ps + 2, 0], jnp.int32)
    qlen = jnp.full((b,), c, jnp.int32)
    kp2 = np.asarray(kp).copy()
    for row in range(b):
        for pos in range(int(ctx[row]), w * ps):
            kp2[0, int(tables[row, pos // ps]), pos % ps] = 127
    case2 = (q, jnp.asarray(kp2), vp, ks, vs, tables, ck, cv, cks, cvs)
    for backend, interp in (("xla", None), ("pallas", True)):
        got = _prefill(case, ctx, qlen, 0, 8, backend, interpret=interp)
        got2 = _prefill(case2, ctx, qlen, 0, 8, backend, interpret=interp)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(got2), atol=1e-6, err_msg=backend
        )
