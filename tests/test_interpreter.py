"""Executable ISA semantics: assembled VSACFG/VSALD/VSAM programs must equal
the plain convolution oracle across precisions, dataflows, kernel sizes."""
import numpy as np
import pytest

from repro.core.assembler import assemble_conv
from repro.core.dataflow import ConvLayer
from repro.core.interpreter import run_program
from repro.core.isa import Dataflow, decode
from repro.core.precision import Precision


def conv_oracle(x, w, pad):
    cin, h, wd = x.shape
    cout, _, k, _ = w.shape
    xp = np.zeros((cin, h + 2 * pad, wd + 2 * pad), np.int64)
    xp[:, pad : pad + h, pad : pad + wd] = x
    ho, wo = h + 2 * pad - k + 1, wd + 2 * pad - k + 1
    out = np.zeros((cout, ho, wo), np.int64)
    for o in range(cout):
        for y in range(ho):
            for xx in range(wo):
                out[o, y, xx] = np.sum(
                    xp[:, y : y + k, xx : xx + k].astype(np.int64)
                    * w[o].astype(np.int64)
                )
    return out


def _mk(prec, cin, cout, h, w, k, seed):
    rng = np.random.default_rng(seed)
    lim = min(prec.spec.qmax, 50)
    x = rng.integers(-lim, lim + 1, (cin, h, w)).astype(np.int32)
    wt = rng.integers(-lim, lim + 1, (cout, cin, k, k)).astype(np.int32)
    return x, wt


@pytest.mark.parametrize("prec", [Precision.INT16, Precision.INT8, Precision.INT4])
@pytest.mark.parametrize("df", [Dataflow.FF, Dataflow.CF])
@pytest.mark.parametrize("k,pad", [(1, 0), (3, 1), (3, 0), (5, 2)])
def test_program_equals_conv(prec, df, k, pad):
    cin, cout, h, w = 8, 8, 6, 6
    if k == 5:
        h = w = 8
    layer = ConvLayer("t", cin, cout, k, h, w, 1, pad)
    x, wt = _mk(prec, cin, cout, h, w, k, seed=k * 10 + pad)
    prog = assemble_conv(layer, x, wt, prec, df)
    got = run_program(prog)
    np.testing.assert_array_equal(got, conv_oracle(x, wt, pad))


@pytest.mark.parametrize("df", [Dataflow.FF, Dataflow.CF])
def test_ragged_channels_and_oc(df):
    """cin not divisible by the element group; cout not divisible by oc_par."""
    prec = Precision.INT8  # group g=4; cin=6 pads to 8
    layer = ConvLayer("t", 6, 10, 3, 6, 6, 1, 1)
    x, wt = _mk(prec, 6, 10, 6, 6, 3, seed=7)
    prog = assemble_conv(layer, x, wt, prec, df)
    np.testing.assert_array_equal(run_program(prog), conv_oracle(x, wt, 1))


def test_bit_accurate_mode_matches():
    """Routing every product through the 4-bit digit decomposition changes
    nothing — the hardware identity end-to-end."""
    prec = Precision.INT8
    layer = ConvLayer("t", 4, 4, 3, 4, 4, 1, 1)
    x, wt = _mk(prec, 4, 4, 4, 4, 3, seed=3)
    prog = assemble_conv(layer, x, wt, prec, Dataflow.CF)
    np.testing.assert_array_equal(
        run_program(prog, bit_accurate=True), run_program(prog, bit_accurate=False)
    )


def test_program_is_decodable_instruction_stream():
    layer = ConvLayer("t", 4, 4, 1, 4, 4, 1, 0)
    x, wt = _mk(Precision.INT16, 4, 4, 4, 4, 1, seed=1)
    prog = assemble_conv(layer, x, wt, Precision.INT16, Dataflow.FF)
    kinds = [type(decode(wd)).__name__ for wd in prog.words]
    assert set(kinds) == {"VSACFG", "VSALD", "VSAM"}
    # FF emits one VSAM chain per (output column, stage); CF one per column
    prog_cf = assemble_conv(layer, x, wt, Precision.INT16, Dataflow.CF)
    n_ff = sum(k == "VSAM" for k in kinds)
    n_cf = sum(type(decode(w)).__name__ == "VSAM" for w in prog_cf.words)
    assert n_ff >= n_cf
