"""End-to-end behaviour of the whole system (the paper's pipeline + the LM
serving integration), on CPU with reduced configs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, cells_for_arch, get_config, list_archs
from repro.core.precision import Precision


def test_paper_pipeline_end_to_end():
    """SPEED's own story: quantize a conv net, pick per-layer dataflows with
    the calibrated model, execute through the multi-precision conv path, and
    get the right numerics."""
    from repro.core.dataflow import ConvLayer
    from repro.core.perfmodel import evaluate_layer
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    layers = [
        ConvLayer("c1", 8, 16, 3, 12, 12, 1, 1),
        ConvLayer("c2", 16, 16, 1, 12, 12, 1, 0),
    ]
    x = jnp.asarray(rng.normal(size=(1, 12, 12, 8)), jnp.float32)
    for layer, bits in zip(layers, (8, 4)):
        w = jnp.asarray(
            rng.normal(size=(layer.k, layer.k, layer.cin, layer.cout)), jnp.float32
        )
        wd, ws = ops.conv_pack_weights(w, bits)
        perf = evaluate_layer(layer, Precision.from_bits(bits))
        assert perf.gops > 0
        x = ops.mpconv(
            x, wd, ws, w_bits=bits, ksize=layer.k, stride=layer.stride,
            padding=layer.padding, dataflow="auto",
        )
        x = jax.nn.relu(x)
    assert x.shape == (1, 12, 12, 16)
    assert np.isfinite(np.asarray(x)).all()


def test_train_then_serve_quantized(tmp_path):
    """Train a tiny LM for 25 steps, quantize to int8, serve greedy tokens."""
    from repro.data.pipeline import DataConfig
    from repro.train import TrainConfig, Trainer
    from repro.train.server import Request, Server

    arch = dataclasses.replace(
        get_config("llama3.2-3b").reduced(),
        n_layers=2, d_model=64, d_ff=128, vocab=256, n_heads=2, n_kv_heads=2,
        head_dim=32, serve_kv_bits=8,
    )
    tc = TrainConfig(lr=3e-3, warmup=5, total_steps=25, ckpt_every=25,
                     ckpt_dir=str(tmp_path))
    data = DataConfig(vocab=arch.vocab, seq_len=64, global_batch=8)
    tr = Trainer(arch=arch, tc=tc, data=data)
    out = tr.run(25)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]

    srv = Server(arch, out["params"], batch_size=2, max_len=96, quantize=True)
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32) + i,
                    max_new_tokens=6) for i in range(2)]
    srv.serve(reqs)
    assert all(len(r.out_tokens) == 6 for r in reqs)
    assert srv.stats.tokens_out == 12


def test_cell_enumeration_matches_assignment():
    """40 assigned (arch x shape) cells; long_500k runs only for sub-quadratic
    archs (6 skips per DESIGN.md SS6) => 34 runnable."""
    archs = list_archs()
    assert len(archs) == 10
    total = sum(len(cells_for_arch(get_config(a))) for a in archs)
    long_archs = {a for a in archs if "long_500k" in cells_for_arch(get_config(a))}
    assert long_archs == {"mixtral-8x22b", "zamba2-7b", "gemma3-1b", "mamba2-130m"}
    assert total == 34
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert 10 * len(SHAPES) == 40


def test_vlm_audio_frontend_stubs():
    from repro.models.frontends import prefix_embeddings, prefix_spec

    cfg = get_config("paligemma-3b").reduced()
    emb = prefix_embeddings(cfg, 2)
    assert emb.shape == (2, cfg.prefix_len, cfg.d_model)
    spec = prefix_spec(cfg, 4)
    assert spec.shape == (4, cfg.prefix_len, cfg.d_model)
    assert np.isfinite(np.asarray(emb, np.float32)).all()


def test_cnn_zoo_matches_paper_workloads():
    from repro.models.cnn_zoo import BENCHMARK_NETWORKS

    nets = {k: f() for k, f in BENCHMARK_NETWORKS.items()}
    assert set(nets) == {"VGG16", "ResNet18", "GoogLeNet", "SqueezeNet"}
    assert len(nets["VGG16"]) == 13  # conv layers only
    assert sum(l.k == 1 for l in nets["GoogLeNet"]) > sum(
        l.k > 1 for l in nets["GoogLeNet"]
    ) / 2  # inception is 1x1-heavy: the mixed-strategy showcase
    # ~paper scale: VGG16 conv MACs ~15.3G
    vgg_macs = sum(l.macs for l in nets["VGG16"])
    assert 14e9 < vgg_macs < 16e9
