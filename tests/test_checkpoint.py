"""Checkpointing: roundtrip, atomicity, keep-N, async, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 10, (4,)), jnp.int32)},
        "scalar": jnp.asarray(3, jnp.int32),
    }


def _assert_tree_equal(a, b):
    flat_a = jax.tree_util.tree_leaves(a)
    flat_b = jax.tree_util.tree_leaves(b)
    for x, y in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t)
    restored, manifest = mgr.restore(target=t)
    _assert_tree_equal(t, restored)
    assert manifest["step"] == 10


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(1)
    mgr.save(5, t, blocking=False)
    mgr.wait()
    restored, _ = mgr.restore(5, target=t)
    _assert_tree_equal(t, restored)


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [3, 4]


def test_atomicity_ignores_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1))
    # simulate a crashed writer: orphan tmp dir with garbage
    os.makedirs(tmp_path / "step_000000002.tmp")
    (tmp_path / "step_000000002.tmp" / "junk").write_text("x")
    assert mgr.latest_step() == 1  # tmp is invisible
    restored, _ = mgr.restore(target=_tree(1))
    _assert_tree_equal(_tree(1), restored)
    mgr.save(3, _tree(3))  # next save prunes orphans
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_restore_latest_and_missing(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore()
    mgr.save(7, _tree(7))
    mgr.save(9, _tree(9))
    restored, m = mgr.restore(target=_tree(0))
    assert m["step"] == 9
    _assert_tree_equal(_tree(9), restored)


def test_elastic_reshard_subprocess(subproc):
    """Save under a (4,1) mesh, restore onto (2,2) — different topology."""
    subproc(
        """
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager

        d = tempfile.mkdtemp()
        mesh1 = jax.make_mesh((4, 1), ("data", "model"))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh1, P("data", None)))
        mgr = CheckpointManager(d)
        mgr.save(1, {"x": xs})

        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        sh2 = {"x": NamedSharding(mesh2, P("data", "model"))}
        restored, _ = mgr.restore(target={"x": x}, shardings=sh2)
        np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
        assert restored["x"].sharding.mesh.shape["model"] == 2
        print("elastic reshard OK")
        """,
        n_devices=4,
    )
