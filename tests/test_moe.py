"""MoE: routing math, capacity dropping, replicated-vs-alltoall dispatch parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import _positions_in_expert, _route, init_moe_params, moe_ffn


def test_positions_in_expert():
    eids = jnp.asarray([2, 0, 2, 1, 2, 0], jnp.int32)
    pos = np.asarray(_positions_in_expert(eids, 3))
    # each expert's tokens numbered 0..count-1 in order of appearance
    assert pos.tolist() == [0, 0, 1, 0, 2, 1]


def test_route_topk_and_aux():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    weights, eids, aux, probs = _route(x, w, top_k=2)
    assert weights.shape == (64, 2) and eids.shape == (64, 2)
    np.testing.assert_allclose(np.asarray(weights.sum(-1)), 1.0, atol=1e-5)
    assert float(aux) > 0.5  # ~1.0 for balanced routing


def test_moe_dense_equivalence_topk_equals_experts():
    """With top_k == n_experts and ample capacity, MoE equals the weighted sum
    of every expert's FFN — a closed-form oracle."""
    rng = np.random.default_rng(1)
    d, f, e = 16, 32, 4
    params = init_moe_params(jax.random.PRNGKey(0), d, f, e, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, d)), jnp.float32)
    out, aux = moe_ffn(x, params, top_k=e, capacity_factor=float(e) * 2)

    x2 = x.reshape(-1, d)
    logits = x2 @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    exp = jnp.zeros_like(x2)
    for j in range(e):
        gate = jax.nn.silu(x2 @ params["wg"][j]) * (x2 @ params["wu"][j])
        exp = exp + probs[:, j:j+1] * (gate @ params["wd"][j])
    np.testing.assert_allclose(
        np.asarray(out.reshape(-1, d)), np.asarray(exp), atol=2e-4, rtol=2e-3
    )


def test_capacity_dropping_no_nans():
    params = init_moe_params(jax.random.PRNGKey(0), 8, 16, 4, jnp.float32)
    x = jnp.ones((1, 64, 8), jnp.float32)  # all tokens route identically
    out, _ = moe_ffn(x, params, top_k=1, capacity_factor=0.1)
    assert np.isfinite(np.asarray(out)).all()
    # most tokens dropped => most outputs zero
    zero_frac = float(jnp.mean(jnp.all(out == 0, axis=-1)))
    assert zero_frac > 0.5


def test_alltoall_matches_replicated(subproc):
    subproc(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.moe import init_moe_params, moe_ffn
        from repro.distributed import sharding as sh

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        params = init_moe_params(jax.random.PRNGKey(0), 16, 32, 4, jnp.float32)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(4, 8, 16)), jnp.float32)

        ref, _ = moe_ffn(x, params, top_k=2, capacity_factor=8.0)

        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            got, aux = jax.jit(
                lambda x, p: moe_ffn(
                    x, p, top_k=2, capacity_factor=8.0,
                    dispatch="alltoall", mesh=mesh,
                )
            )(x, params)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4, rtol=1e-3)
        print("alltoall EP OK")
        """,
        n_devices=4,
    )
