"""Continuous-batching engine: scheduler invariants, paged KV cache reuse,
mixed-precision grouping, and batched-vs-sequential decode equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import (
    PagedKVCache,
    PrecisionParams,
    RequestState,
    SamplingParams,
    Scheduler,
    ServeEngine,
    ServeRequest,
)


def _req(rid, arrival, prompt_len=8, max_new=4, w_bits=8, kv_bits=8):
    return ServeRequest(
        rid=rid,
        prompt=np.arange(prompt_len, dtype=np.int32),
        max_new_tokens=max_new,
        w_bits=w_bits,
        kv_bits=kv_bits,
        arrival=arrival,
    )


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(
        get_config("llama3.2-3b").reduced(),
        n_layers=2, d_model=64, d_ff=128, vocab=128, n_heads=2, n_kv_heads=2,
        head_dim=32, serve_kv_bits=16,
    )
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------------- scheduler invariants
def test_scheduler_capacity():
    """Running set never exceeds max_slots even with everything admissible."""
    sched = Scheduler(max_slots=2)
    for i in range(5):
        sched.submit(_req(i, i))
    admitted = sched.admit(lambda r: True)
    assert len(admitted) == 2
    assert len(sched.running) == 2
    assert sched.admit(lambda r: True) == []  # slots full
    sched.finish(sched.running[0])
    assert [r.rid for r in sched.admit(lambda r: True)] == [2]  # FCFS refill


def test_no_starvation_head_of_line():
    """A non-fitting head blocks younger requests from bypassing it."""
    sched = Scheduler(max_slots=4)
    big = _req(0, 0, prompt_len=100)
    small = _req(1, 1, prompt_len=2)
    sched.submit(big)
    sched.submit(small)
    admitted = sched.admit(lambda r: len(r.prompt) <= 10)
    assert admitted == []  # small never jumps the queue
    admitted = sched.admit(lambda r: True)
    assert [r.rid for r in admitted] == [0, 1]  # arrival order preserved


def test_preempt_requeues_in_arrival_order():
    sched = Scheduler(max_slots=3)
    for i in range(3):
        sched.submit(_req(i, i))
    sched.admit(lambda r: True)
    victim = sched.pick_victim()
    assert victim.arrival == 2  # youngest
    sched.preempt(victim)
    assert victim.state is RequestState.WAITING
    assert victim.preemptions == 1
    sched.submit(_req(9, 9))
    # preempted (arrival 2) sits ahead of the newer arrival 9
    assert [r.arrival for r in sched.waiting] == [2, 9]


# ----------------------------------------------------------- paged KV cache
def _tiny_cache(**kw):
    cfg = dataclasses.replace(
        get_config("llama3.2-3b").reduced(), n_layers=2, n_kv_heads=2, head_dim=8
    )
    defaults = dict(num_pages=4, page_size=4, kv_bits=8)
    defaults.update(kw)
    return PagedKVCache(cfg, **defaults)


def test_kv_page_capacity_and_reuse():
    cache = _tiny_cache()
    a = cache.allocate(0, 3)
    assert not cache.can_allocate(2)
    with pytest.raises(MemoryError):
        cache.allocate(1, 2)
    cache.free(0)
    b = cache.allocate(1, 3)
    assert b == a  # LIFO free list: freed pages reused immediately
    assert cache.stats().high_water == 3


def test_kv_write_gather_roundtrip():
    """Prompt scatter + per-token scatter land at the right positions."""
    cache = _tiny_cache(kv_bits=16)
    L, ps = 2, 4
    hkv, hd = cache.k.shape[3], cache.k.shape[4]
    cache.allocate(7, 2)
    row = jnp.arange(L * 2 * ps * hkv * hd, dtype=jnp.float32).reshape(
        L, 2 * ps, hkv, hd
    )
    cache.write_prompt(7, row, row * 2)
    tok_k = jnp.full((L, 1, hkv, hd), -1.0)
    cache.write_token([7], np.array([5]), (tok_k, tok_k))
    table = jnp.asarray(cache.table(7), jnp.int32)
    got = cache.k[:, table].reshape(L, 2 * ps, hkv, hd)
    expect = row.astype(got.dtype).at[:, 5].set(-1.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


# ------------------------------------------------- engine: precision grouping
def test_mixed_precision_grouping(setup):
    cfg, params = setup
    cfg = dataclasses.replace(cfg, serve_kv_bits=8)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32) for _ in range(4)]

    eng = ServeEngine(cfg, params, max_slots=4, num_pages=32, page_size=8)
    mixed = [
        eng.submit(p, SamplingParams(max_new_tokens=5), PrecisionParams(w_bits=4 if i % 2 else 8, kv_bits=8))
        for i, p in enumerate(prompts)
    ]
    eng.run()
    assert all(r.done and len(r.out_tokens) == 5 for r in mixed)
    assert eng.stats.mixed_precision_steps > 0  # W4 and W8 decoded in one step
    assert set(eng.stats.group_calls) == {(4, 8), (8, 8)}

    # each group's tokens match a single-precision engine run
    for bits in (4, 8):
        solo_eng = ServeEngine(cfg, params, max_slots=4, num_pages=32, page_size=8)
        solo = [
            solo_eng.submit(p, SamplingParams(max_new_tokens=5), PrecisionParams(w_bits=bits, kv_bits=8))
            for i, p in enumerate(prompts)
            if (4 if i % 2 else 8) == bits
        ]
        solo_eng.run()
        mixed_same = [r for i, r in enumerate(mixed) if (4 if i % 2 else 8) == bits]
        assert [r.out_tokens for r in solo] == [r.out_tokens for r in mixed_same]


# --------------------------------------- batched vs sequential vs manual loop
def test_batched_equals_sequential(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32) for _ in range(3)]

    batched = ServeEngine(cfg, params, max_slots=3, num_pages=24, page_size=8)
    br = [batched.submit(p, SamplingParams(max_new_tokens=4), PrecisionParams(w_bits=16, kv_bits=16)) for p in prompts]
    batched.run()

    seq_tokens = []
    for p in prompts:
        eng = ServeEngine(cfg, params, max_slots=1, num_pages=8, page_size=8)
        r = eng.submit(p, SamplingParams(max_new_tokens=4), PrecisionParams(w_bits=16, kv_bits=16))
        eng.run()
        seq_tokens.append(r.out_tokens)
    assert [r.out_tokens for r in br] == seq_tokens


def test_engine_matches_manual_decode_loop(setup):
    """Paged ragged decode == models.transformer prefill + decode_step."""
    cfg, params = setup
    prompt = np.arange(1, 9, dtype=np.int32)
    eng = ServeEngine(cfg, params, max_slots=1, num_pages=8, page_size=8)
    req = eng.submit(prompt, SamplingParams(max_new_tokens=4), PrecisionParams(w_bits=16, kv_bits=16))
    eng.run()

    logits, cache = T.prefill(params, {"tokens": jnp.asarray(prompt)[None]}, cfg, 64)
    manual = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        manual.append(int(tok[0, 0]))
        logits, cache = T.decode_step(params, tok, cache, cfg)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert req.out_tokens == manual


def test_paged_gather_matches_ref_oracle(setup):
    """The paged layout feeds attention the same values as a dense cache:
    gathered pages through the kernel wrapper == kernels/ref.py oracle."""
    from repro.kernels import ops, ref

    cfg, _ = setup
    cache = PagedKVCache(cfg, num_pages=6, page_size=4, kv_bits=8)
    L, hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    s = 12  # 3 pages
    rng = np.random.default_rng(2)
    kq = rng.integers(-127, 128, (L, s, hkv, hd)).astype(np.int8)
    vq = rng.integers(-127, 128, (L, s, hkv, hd)).astype(np.int8)
    ks = rng.random((L, s, hkv, 1)).astype(np.float32) * 0.1
    vs = rng.random((L, s, hkv, 1)).astype(np.float32) * 0.1
    cache.allocate(0, 3)
    cache.write_prompt(0, jnp.asarray(kq), jnp.asarray(vq), jnp.asarray(ks), jnp.asarray(vs))

    tables = cache.table_array([0], width=4)  # padded wider than needed
    gk = ref.gather_pages(cache.k, tables)
    gv = ref.gather_pages(cache.v, tables)
    gks = ref.gather_pages(cache.k_scale, tables)
    gvs = ref.gather_pages(cache.v_scale, tables)

    q = jnp.asarray(rng.standard_normal((1, cfg.n_heads, hd)), jnp.float32)
    lengths = jnp.asarray([10], jnp.int32)  # ragged: shorter than stored
    layer = 0
    got = ops.mqa_decode(
        q, gk[layer], gv[layer], gks[layer], gvs[layer], lengths, kv_bits=8, bs=8
    )
    want = ref.mqa_decode_ref(
        q, gk[layer], gv[layer], gks[layer], gvs[layer], lengths,
        sm_scale=1.0 / np.sqrt(hd),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)
    # and the gather itself reproduced the dense rows
    np.testing.assert_array_equal(np.asarray(gk[:, 0, :s]), kq)


# ------------------------------------------------------ preemption + refill
def test_preemption_recovers(setup):
    """Pool too small for all requests: youngest gets preempted, everyone
    still finishes with a full token budget."""
    cfg, params = setup
    cfg = dataclasses.replace(cfg, serve_kv_bits=8)
    rng = np.random.default_rng(3)
    eng = ServeEngine(cfg, params, max_slots=3, num_pages=4, page_size=4)
    reqs = [
        eng.submit(rng.integers(0, cfg.vocab, 6).astype(np.int32), SamplingParams(max_new_tokens=8), PrecisionParams(w_bits=8))
        for _ in range(3)
    ]
    eng.run()
    assert all(r.done and len(r.out_tokens) == 8 for r in reqs)
    assert eng.stats.preemptions > 0
    # every page is reclaimable again: free, or retained (refcount 0) by the
    # prefix cache for future hits
    cache = eng.cache_for(8)
    assert cache.num_allocatable == 4
    assert not cache._tables and not cache._refcount


def test_continuous_refill(setup):
    """More requests than slots: finished slots refill without wave barriers
    and capacity is respected throughout."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    eng = ServeEngine(cfg, params, max_slots=2, num_pages=16, page_size=8)
    reqs = [
        eng.submit(rng.integers(0, cfg.vocab, 8).astype(np.int32), SamplingParams(max_new_tokens=3 + i), PrecisionParams(w_bits=16))
        for i in range(5)
    ]
    while eng._sched.has_work():
        assert len(eng._sched.running) <= 2
        eng.step()
    assert all(r.done and len(r.out_tokens) == 3 + i for i, r in enumerate(reqs))
