"""Serving engine: batched waves, greedy decode, quantized weights."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.train.server import Request, Server


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(
        get_config("llama3.2-3b").reduced(),
        n_layers=2, d_model=64, d_ff=128, vocab=128, n_heads=2, n_kv_heads=2,
        head_dim=32, serve_kv_bits=16,
    )
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_serve_greedy_batch(setup):
    cfg, params = setup
    srv = Server(cfg, params, batch_size=2, max_len=64, quantize=False)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new_tokens=5)
        for i in range(3)  # forces two waves at batch_size=2
    ]
    out = srv.serve(reqs)
    assert all(r.done for r in out)
    assert all(len(r.out_tokens) == 5 for r in out)
    assert all(0 <= t < cfg.vocab for r in out for t in r.out_tokens)
    assert srv.stats.tokens_out == 15
    assert srv.stats.decode_steps >= 5


def test_serve_quantized_runs(setup):
    cfg, params = setup
    srv = Server(cfg, params, batch_size=2, max_len=64, quantize=True)
    reqs = [Request(rid=0, prompt=np.arange(6, dtype=np.int32), max_new_tokens=4)]
    out = srv.serve(reqs)
    assert len(out[0].out_tokens) == 4


def test_serve_matches_manual_loop(setup):
    """Engine greedy tokens == manual prefill+decode loop."""
    import jax.numpy as jnp

    cfg, params = setup
    prompt = np.arange(1, 9, dtype=np.int32)
    srv = Server(cfg, params, batch_size=1, max_len=64, quantize=False)
    req = Request(rid=0, prompt=prompt, max_new_tokens=4)
    srv.serve([req])

    batch = {"tokens": jnp.asarray(prompt)[None]}
    logits, cache = T.prefill(params, batch, cfg, max_len=64)
    manual = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        manual.append(int(tok[0, 0]))
        logits, cache = T.decode_step(params, tok, cache, cfg)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert req.out_tokens == manual
