"""The multi-precision multiplier-combination identity (paper Sec. II-B):
sixteen 4-bit multipliers == 1x16b / 4x8b / 16x4b MACs, bit-exactly."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.precision import PE_MULTIPLIERS_4B, Precision
from repro.core.sau import SAU, digit_compose, digit_decompose, pe_mac, pe_multiply

PRECS = [Precision.INT4, Precision.INT8, Precision.INT16]


def _rng_ints(prec, shape, seed=0):
    s = prec.spec
    return np.random.default_rng(seed).integers(s.qmin, s.qmax + 1, shape).astype(np.int32)


@settings(max_examples=300, deadline=None)
@given(st.integers(-(2 ** 15), 2 ** 15 - 1), st.sampled_from([4, 8, 16]))
def test_digit_roundtrip(x, bits):
    qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    x = max(qmin, min(qmax, x))
    digits = digit_decompose(jnp.asarray([x]), bits)
    assert digits.shape[-1] == bits // 4
    back = digit_compose(digits)
    assert int(back[0]) == x


@settings(max_examples=300, deadline=None)
@given(st.data(), st.sampled_from(PRECS))
def test_pe_multiply_equals_direct(data, prec):
    s = prec.spec
    a = data.draw(st.integers(s.qmin, s.qmax))
    b = data.draw(st.integers(s.qmin, s.qmax))
    got = pe_multiply(jnp.asarray([a]), jnp.asarray([b]), prec)
    assert int(got[0]) == a * b
    # the mode uses exactly the sixteen 4-bit multipliers
    assert s.digits * s.digits * s.macs_per_pe == PE_MULTIPLIERS_4B


@pytest.mark.parametrize("prec", PRECS)
def test_pe_multiply_extremes(prec):
    s = prec.spec
    vals = jnp.asarray([s.qmin, s.qmax, -1, 0, 1], jnp.int32)
    got = pe_multiply(vals[:, None], vals[None, :], prec)
    exp = vals[:, None].astype(jnp.int64) * vals[None, :].astype(jnp.int64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@pytest.mark.parametrize("prec", PRECS)
def test_pe_mac_accumulates(prec):
    acc = jnp.asarray([7], jnp.int32)
    out = pe_mac(acc, jnp.asarray([3]), jnp.asarray([-5]), prec)
    assert int(out[0]) == 7 - 15


@pytest.mark.parametrize("prec", PRECS)
@pytest.mark.parametrize("bit_accurate", [False, True])
def test_sau_matmul(prec, bit_accurate):
    sau = SAU(tile_r=4, tile_c=4)
    a = jnp.asarray(_rng_ints(prec, (4, 6), 1))
    b = jnp.asarray(_rng_ints(prec, (6, 4), 2))
    acc = jnp.zeros((4, 4), jnp.int32)
    out = sau(acc, a, b, prec, bit_accurate=bit_accurate)
    exp = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(np.asarray(out), exp.astype(np.int32))


def test_sau_rejects_oversized():
    sau = SAU(tile_r=2, tile_c=2)
    with pytest.raises(ValueError):
        sau(jnp.zeros((4, 4), jnp.int32), jnp.zeros((4, 3), jnp.int32),
            jnp.zeros((3, 4), jnp.int32), Precision.INT8)


def test_sau_cycles_model():
    sau = SAU(tile_r=4, tile_c=4)
    c1 = sau.cycles(4, 4, 100, Precision.INT8)
    c2 = sau.cycles(8, 4, 100, Precision.INT8)  # two row tiles
    assert c2 == 2 * c1
