"""Per-architecture smoke tests (reduced configs, CPU): one forward/train step
with shape + finiteness asserts, prefill/decode exactness, quantized serving.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
ARCHS = list_archs()


def _batch(cfg, b=2, s=32):
    tok = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    if cfg.prefix_len:
        batch["prefix_emb"] = jax.random.normal(
            KEY, (b, cfg.prefix_len, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_smoke(name):
    cfg = get_config(name).reduced()
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg)
    loss, metrics = T.train_loss(params, batch, cfg)
    assert np.isfinite(float(loss)) and float(loss) > 0
    # gradients exist and are finite for every leaf
    grads = jax.grad(lambda p: T.train_loss(p, batch, cfg)[0])(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_exact(name):
    cfg = dataclasses.replace(
        get_config(name).reduced(), serve_kv_bits=16, capacity_factor=8.0
    )
    params = T.init_params(cfg, KEY)
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    ml = s + cfg.prefix_len + 8
    logits_p, cache = T.prefill(params, batch, cfg, max_len=ml)
    assert logits_p.shape == (b, cfg.padded_vocab)
    nxt = jnp.argmax(logits_p, -1)[:, None]
    logits_d, cache2 = T.decode_step(params, nxt, cache, cfg)
    assert int(cache2["pos"]) == int(cache["pos"]) + 1
    batch2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], axis=1))
    logits_p2, _ = T.prefill(params, batch2, cfg, max_len=ml)
    rel = float(jnp.max(jnp.abs(logits_d - logits_p2))) / max(
        float(jnp.max(jnp.abs(logits_p2))), 1e-6
    )
    tol = 2e-2 if cfg.family in ("ssm", "hybrid") or cfg.local_ratio else 1e-4
    assert rel < tol, rel


@pytest.mark.parametrize("name", ["yi-9b", "kimi-k2-1t-a32b", "mamba2-130m"])
def test_quantized_serving_close(name):
    """w8-quantized weights keep greedy argmax plausible (top-1 overlap or
    tight logit distance) — the multi-precision serving path end-to-end."""
    cfg = dataclasses.replace(get_config(name).reduced(), serve_kv_bits=16)
    params = T.init_params(cfg, KEY)
    qparams = T.quantize_params(params, 8)
    batch = _batch(cfg)
    ml = 48
    lf, _ = T.prefill(params, batch, cfg, max_len=ml)
    lq, _ = T.prefill(qparams, batch, cfg, max_len=ml)
    denom = float(jnp.max(jnp.abs(lf)))
    rel = float(jnp.max(jnp.abs(lf - lq))) / max(denom, 1e-6)
    assert rel < 0.35, rel  # int8 per-channel keeps logits in range


def test_quantize_params_payload_shrinks():
    cfg = get_config("yi-9b").reduced()
    params = T.init_params(cfg, KEY)

    def nbytes(tree):
        return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree))

    q8 = T.quantize_params(params, 8)
    q4 = T.quantize_params(params, 4)
    assert nbytes(q8) < nbytes(params)
    assert nbytes(q4) < nbytes(q8)


def test_gemma3_local_global_pattern():
    """Every 6th layer global: a token beyond the local window influences the
    output only through global layers; with window math disabled it must
    differ from fully-local attention."""
    cfg = get_config("gemma3-1b").reduced()
    assert cfg.local_ratio == 5 and cfg.window is not None
    from repro.models.transformer import _per_layer_window

    wins = np.asarray(_per_layer_window(cfg, 12))
    assert (wins[5] > 10**6) and (wins[11] > 10**6)
    assert (wins[[0, 1, 2, 3, 4, 6]] == cfg.window).all()


def test_param_count_sanity():
    """Config-level parameter accounting matches the actual pytrees within 2%
    for a dense arch (reduced)."""
    cfg = get_config("llama3.2-3b").reduced()
    params = T.init_params(cfg, KEY)
    actual = sum(
        l.size for p, l in jax.tree_util.tree_leaves_with_path(params)
        if "norm" not in jax.tree_util.keystr(p)
    )
    approx = cfg.param_count() - 2 * cfg.vocab * cfg.d_model + 2 * cfg.padded_vocab * cfg.d_model
    assert abs(actual - approx) / approx < 0.02


def test_full_config_param_counts():
    """The headline sizes: kimi ~1T total / ~32B active, mixtral ~140B."""
    kimi = get_config("kimi-k2-1t-a32b")
    assert 0.9e12 < kimi.param_count() < 1.3e12
    assert 25e9 < kimi.active_param_count() < 40e9
    mixtral = get_config("mixtral-8x22b")
    assert 120e9 < mixtral.param_count() < 160e9
