"""Flash-decode kernel vs oracle: GQA, quantized KV (int8/int4), ragged lengths."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quant.pack import unpack_int4

RNG = np.random.default_rng(0)


def _case(b, s, hkv, groups, d, kv_bits):
    h = hkv * groups
    q = jnp.asarray(RNG.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    kd, ks = ops.quantize_kv(k, kv_bits)
    vd, vs = ops.quantize_kv(v, kv_bits)
    return q, kd, vd, ks, vs


def _oracle(q, kd, vd, ks, vs, lengths, kv_bits, d):
    kdu = unpack_int4(kd, axis=-1) if kv_bits == 4 else kd
    vdu = unpack_int4(vd, axis=-1) if kv_bits == 4 else vd
    return ref.mqa_decode_ref(q, kdu, vdu, ks, vs, lengths, sm_scale=1.0 / np.sqrt(d))


@pytest.mark.parametrize("kv_bits", [8, 4])
@pytest.mark.parametrize(
    "b,s,hkv,groups,d,bs",
    [
        (2, 512, 2, 4, 64, 128),
        (1, 1024, 1, 8, 128, 256),
        (3, 384, 4, 1, 64, 128),  # MHA (groups=1), non-pow2 batch
    ],
)
def test_decode_sweep(kv_bits, b, s, hkv, groups, d, bs):
    q, kd, vd, ks, vs = _case(b, s, hkv, groups, d, kv_bits)
    lengths = jnp.asarray([s - 7 * i for i in range(b)], jnp.int32)
    got = ops.mqa_decode(q, kd, vd, ks, vs, lengths, kv_bits=kv_bits, bs=bs)
    exp = _oracle(q, kd, vd, ks, vs, lengths, kv_bits, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-3, rtol=3e-3)


def test_short_lengths_mask_everything_beyond():
    b, s, hkv, groups, d = 2, 512, 2, 2, 64
    q, kd, vd, ks, vs = _case(b, s, hkv, groups, d, 8)
    lengths = jnp.asarray([5, 1], jnp.int32)
    got = ops.mqa_decode(q, kd, vd, ks, vs, lengths, kv_bits=8, bs=128)
    exp = _oracle(q, kd, vd, ks, vs, lengths, 8, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-3, rtol=3e-3)
    # corrupting cache beyond the valid length must not change the output
    kd2 = kd.at[:, 10:].set(127)
    got2 = ops.mqa_decode(q, kd2, vd, ks, vs, lengths, kv_bits=8, bs=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got2), atol=1e-6)


def test_non_multiple_seq_padding():
    b, s, hkv, groups, d = 2, 300, 2, 2, 64  # s not a multiple of bs
    q, kd, vd, ks, vs = _case(b, s, hkv, groups, d, 8)
    lengths = jnp.asarray([300, 123], jnp.int32)
    got = ops.mqa_decode(q, kd, vd, ks, vs, lengths, kv_bits=8, bs=128)
    exp = _oracle(q, kd, vd, ks, vs, lengths, 8, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-3, rtol=3e-3)


def test_kv4_halves_payload():
    k = jnp.asarray(RNG.normal(size=(1, 128, 2, 64)), jnp.float32)
    k8, _ = ops.quantize_kv(k, 8)
    k4, _ = ops.quantize_kv(k, 4)
    assert k4.size == k8.size // 2
