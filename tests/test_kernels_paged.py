"""Paged flash-decode kernel vs oracles: page-table indirection, quantized
pools (int8/int4/bf16), ragged lengths (0 / page-boundary / full-table), the
fused new-token term, and append-then-attend round trips."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.kernels.mqa_decode import mqa_decode_pallas
from repro.quant.pack import pack_int4
from repro.serve.kv_cache import PagedKVCache

RNG = np.random.default_rng(0)


def _case(b, n_layers, n_pages, ps, w, hkv, groups, d, kv_bits):
    """Random pool + shuffled page tables + this step's token."""
    h = hkv * groups
    q = jnp.asarray(RNG.normal(size=(b, h, d)), jnp.float32)
    if kv_bits < 16:
        lim = 8 if kv_bits == 4 else 128
        kp = RNG.integers(-lim, lim, (n_layers, n_pages, ps, hkv, d)).astype(np.int8)
        vp = RNG.integers(-lim, lim, (n_layers, n_pages, ps, hkv, d)).astype(np.int8)
        ks = (RNG.random((n_layers, n_pages, ps, hkv, 1)) * 0.1).astype(np.float32)
        vs = (RNG.random((n_layers, n_pages, ps, hkv, 1)) * 0.1).astype(np.float32)
        nk = RNG.integers(-lim, lim, (b, hkv, d)).astype(np.int8)
        nv = RNG.integers(-lim, lim, (b, hkv, d)).astype(np.int8)
        nks = (RNG.random((b, hkv, 1)) * 0.1).astype(np.float32)
        nvs = (RNG.random((b, hkv, 1)) * 0.1).astype(np.float32)
    else:
        kp = RNG.normal(size=(n_layers, n_pages, ps, hkv, d)).astype(np.float32)
        vp = RNG.normal(size=(n_layers, n_pages, ps, hkv, d)).astype(np.float32)
        ks = vs = nks = nvs = None
        nk = RNG.normal(size=(b, hkv, d)).astype(np.float32)
        nv = RNG.normal(size=(b, hkv, d)).astype(np.float32)
    # every row gets distinct pages, shuffled: the table indirection matters
    tables = RNG.permutation(n_pages)[: b * w].reshape(b, w).astype(np.int32)
    J = lambda x: None if x is None else jnp.asarray(x)
    return (
        q, J(kp), J(vp), J(ks), J(vs), jnp.asarray(tables),
        J(nk), J(nv), J(nks), J(nvs),
    )


def _packed(x, kv_bits):
    if x is None or kv_bits != 4:
        return x
    return pack_int4(x, axis=-1)


def _paged(case, lengths, layer, kv_bits, backend, window=None, interpret=None):
    q, kp, vp, ks, vs, tables, nk, nv, nks, nvs = case
    return ops.paged_mqa_decode(
        q, _packed(kp, kv_bits), _packed(vp, kv_bits), ks, vs, tables, lengths,
        layer, _packed(nk, kv_bits), _packed(nv, kv_bits), nks, nvs,
        kv_bits=kv_bits, window=window, backend=backend, interpret=interpret,
    )


def _oracle(case, lengths, layer, d, window=None):
    q, kp, vp, ks, vs, tables, nk, nv, nks, nvs = case
    return ref.paged_mqa_decode_ref(
        q, kp, vp, ks, vs, tables, lengths, layer, nk, nv, nks, nvs,
        sm_scale=1.0 / np.sqrt(d), window=window,
    )


@pytest.mark.parametrize("kv_bits", [8, 4, 16])
@pytest.mark.parametrize(
    "b,hkv,groups,d,ps,w",
    [
        (2, 2, 4, 64, 8, 4),
        (3, 1, 8, 32, 16, 3),  # MQA, non-pow2 batch/width
        (2, 4, 1, 64, 4, 5),  # MHA
    ],
)
def test_paged_matches_oracle(kv_bits, b, hkv, groups, d, ps, w):
    n_layers, n_pages = 2, b * w
    case = _case(b, n_layers, n_pages, ps, w, hkv, groups, d, kv_bits)
    s = w * ps
    # ragged: full-table, page-boundary, zero-length rows
    lengths = jnp.asarray([s, 2 * ps, 0][:b], jnp.int32)
    for layer in range(n_layers):
        exp = _oracle(case, lengths, layer, d)
        for backend, interp in (("xla", None), ("pallas", True)):
            got = _paged(case, lengths, layer, kv_bits, backend, interpret=interp)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(exp), atol=3e-3, rtol=3e-3,
                err_msg=f"{backend} layer={layer}",
            )


def test_paged_matches_dense_reference():
    """Pool + table indirection == contiguous cache: gather the pages by
    table, insert the new token at its position, run the dense oracle."""
    b, hkv, groups, d, ps, w, kv_bits = 2, 2, 2, 32, 8, 4, 8
    case = _case(b, 1, b * w, ps, w, hkv, groups, d, kv_bits)
    q, kp, vp, ks, vs, tables, nk, nv, nks, nvs = case
    s = w * ps
    lengths = jnp.asarray([s - 5, ps], jnp.int32)
    got = _paged(case, lengths, 0, kv_bits, "xla")

    rows = np.arange(b)
    dense = lambda pool: np.asarray(pool[0])[np.asarray(tables)].reshape(
        b, s, *pool.shape[3:]
    )
    kd = jnp.asarray(dense(kp)).at[rows, lengths].set(nk)
    vd = jnp.asarray(dense(vp)).at[rows, lengths].set(nv)
    ksd = jnp.asarray(dense(ks)).at[rows, lengths].set(nks)
    vsd = jnp.asarray(dense(vs)).at[rows, lengths].set(nvs)
    exp = ref.mqa_decode_ref(
        q, kd, vd, ksd, vsd, lengths + 1, sm_scale=1.0 / np.sqrt(d)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-3, rtol=3e-3)


def test_zero_length_attends_only_to_new_token():
    """lengths == 0: softmax spans exactly the fused new token, so the
    output is its dequantized V — and nothing is read from the pool."""
    b, hkv, groups, d, ps, w = 2, 2, 3, 32, 8, 4
    case = _case(b, 1, b * w, ps, w, hkv, groups, d, 8)
    q, kp, vp, ks, vs, tables, nk, nv, nks, nvs = case
    # poison the pool: it must not leak into a zero-length row
    case = (q, kp.at[:].set(127), vp.at[:].set(127), ks, vs, tables, nk, nv, nks, nvs)
    lengths = jnp.zeros((b,), jnp.int32)
    exp = (nv.astype(jnp.float32) * nvs).astype(np.float32)  # [B, Hkv, D]
    exp = np.repeat(np.asarray(exp), groups, axis=1).reshape(b, hkv * groups, d)
    for backend, interp in (("xla", None), ("pallas", True)):
        got = _paged(case, lengths, 0, 8, backend, interpret=interp)
        np.testing.assert_allclose(np.asarray(got), exp, atol=1e-5, rtol=1e-5)


def test_window_masking_matches_oracle():
    b, hkv, groups, d, ps, w = 2, 2, 2, 32, 8, 4
    case = _case(b, 1, b * w, ps, w, hkv, groups, d, 8)
    lengths = jnp.asarray([w * ps - 1, 2 * ps], jnp.int32)
    for window in (5, ps, 2 * ps + 3):
        exp = _oracle(case, lengths, 0, d, window=window)
        for backend, interp in (("xla", None), ("pallas", True)):
            got = _paged(case, lengths, 0, 8, backend, window=window, interpret=interp)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(exp), atol=3e-3, rtol=3e-3,
                err_msg=f"{backend} window={window}",
            )


def test_stale_pool_entries_beyond_length_are_dead():
    """Corrupting pages past each row's length must not change the output
    (the clamped index map may still *fetch* them, never *use* them)."""
    b, hkv, groups, d, ps, w = 2, 2, 2, 32, 8, 4
    case = _case(b, 1, b * w, ps, w, hkv, groups, d, 8)
    q, kp, vp, ks, vs, tables, nk, nv, nks, nvs = case
    lengths = jnp.asarray([ps + 3, 1], jnp.int32)
    # corrupt every position >= its row's length through the table view
    kp2 = np.asarray(kp).copy()
    for row in range(b):
        ln = int(lengths[row])
        for pos in range(ln, w * ps):
            kp2[0, int(tables[row, pos // ps]), pos % ps] = 127
    case2 = (q, jnp.asarray(kp2), vp, ks, vs, tables, nk, nv, nks, nvs)
    for backend, interp in (("xla", None), ("pallas", True)):
        got = _paged(case, lengths, 0, 8, backend, interpret=interp)
        got2 = _paged(case2, lengths, 0, 8, backend, interpret=interp)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(got2), atol=1e-6, err_msg=backend
        )


def _tiny_cfg():
    return dataclasses.replace(
        get_config("llama3.2-3b").reduced(),
        n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16, d_model=64,
    )


def test_append_then_attend_roundtrip():
    """Fused path (attend with new token, then scatter into the page) ==
    store-first path (write_token, then attend over the stored cache)."""
    cfg = _tiny_cfg()
    cache = PagedKVCache(cfg, num_pages=8, page_size=4, kv_bits=8)
    L, hkv, hd, ps = cfg.n_layers, cfg.n_kv_heads, cfg.hd, 4
    n_tok = 9  # mid-page: the append lands in an allocated page
    cache.allocate(0, 3)
    kq = RNG.integers(-127, 128, (L, 12, hkv, hd)).astype(np.int8)
    vq = RNG.integers(-127, 128, (L, 12, hkv, hd)).astype(np.int8)
    ks = (RNG.random((L, 12, hkv, 1)) * 0.1).astype(np.float32)
    vs = (RNG.random((L, 12, hkv, 1)) * 0.1).astype(np.float32)
    kq[:, n_tok:] = 0
    cache.write_prompt(0, jnp.asarray(kq), jnp.asarray(vq), jnp.asarray(ks), jnp.asarray(vs))

    q = jnp.asarray(RNG.normal(size=(1, cfg.n_heads, hd)), jnp.float32)
    nk = RNG.integers(-127, 128, (1, hkv, hd)).astype(np.int8)
    nv = RNG.integers(-127, 128, (1, hkv, hd)).astype(np.int8)
    nks = (RNG.random((1, hkv, 1)) * 0.1).astype(np.float32)
    nvs = (RNG.random((1, hkv, 1)) * 0.1).astype(np.float32)
    tables = cache.table_array([0], width=3)
    lengths = jnp.asarray([n_tok], jnp.int32)

    fused = {
        layer: _paged(
            (q, cache.k, cache.v, cache.k_scale, cache.v_scale, tables,
             jnp.asarray(nk), jnp.asarray(nv), jnp.asarray(nks), jnp.asarray(nvs)),
            lengths, layer, 8, "xla",
        )
        for layer in range(L)
    }

    # now store the token and attend over the updated pool with a zeroed
    # "new token" contribution excluded by comparing against the ref oracle
    per_layer_k = np.broadcast_to(nk[None], (L, 1, hkv, hd))
    per_layer_v = np.broadcast_to(nv[None], (L, 1, hkv, hd))
    per_layer_ks = np.broadcast_to(nks[None], (L, 1, hkv, 1))
    per_layer_vs = np.broadcast_to(nvs[None], (L, 1, hkv, 1))
    cache.write_token(
        [0], np.array([n_tok]),
        (jnp.asarray(per_layer_k), jnp.asarray(per_layer_v),
         jnp.asarray(per_layer_ks), jnp.asarray(per_layer_vs)),
    )
    gk = ref.gather_pages(cache.k, tables)
    gv = ref.gather_pages(cache.v, tables)
    gks = ref.gather_pages(cache.k_scale, tables)
    gvs = ref.gather_pages(cache.v_scale, tables)
    for layer in range(L):
        stored = ref.mqa_decode_ref(
            q, gk[layer], gv[layer], gks[layer], gvs[layer],
            lengths + 1, sm_scale=1.0 / np.sqrt(hd),
        )
        np.testing.assert_allclose(
            np.asarray(fused[layer]), np.asarray(stored), atol=3e-3, rtol=3e-3
        )


def test_decode_step_padding_rows_leave_pool_untouched():
    """pow2-bucket padding rows (valid=False) must not scatter into page 0."""
    import jax

    from repro.models import transformer as T
    from repro.serve.decode import paged_decode_step

    cfg = dataclasses.replace(_tiny_cfg(), vocab=64, serve_kv_bits=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = PagedKVCache(cfg, num_pages=4, page_size=4, kv_bits=8)
    cache.allocate(0, 2)
    before = np.asarray(cache.k)
    tokens = jnp.zeros((2, 1), jnp.int32)
    lengths = jnp.asarray([2, 0], jnp.int32)
    tables = cache.table_array([0], width=2)
    tables = jnp.concatenate([tables, jnp.zeros_like(tables)], axis=0)
    valid = jnp.asarray([True, False])
    logits, pools = paged_decode_step(
        params, tokens, lengths, tables, valid,
        cache.k, cache.v, cache.k_scale, cache.v_scale, cfg=cfg,
    )
    assert logits.shape == (2, params["unembed"].shape[-1])
    after = np.asarray(pools[0])
    # row 0's token landed at page table(0)[0], offset 2
    page0 = cache.table(0)[0]
    assert not np.array_equal(after[:, page0, 2], before[:, page0, 2])
    # padding row wrote nowhere: pool page 0 offset 0 (its zero table entry)
    np.testing.assert_array_equal(after[:, 0, 0], before[:, 0, 0])


def test_mqa_decode_pallas_pads_non_multiple_widths():
    """The raw kernel no longer asserts s % bs == 0 — it pads and masks."""
    b, s, hkv, groups, d, bs = 2, 300, 2, 2, 64, 128
    h = hkv * groups
    q = jnp.asarray(RNG.normal(size=(b, hkv, groups, d)), jnp.float32)
    kd = jnp.asarray(RNG.integers(-127, 128, (b, s, hkv, d)), jnp.int8)
    vd = jnp.asarray(RNG.integers(-127, 128, (b, s, hkv, d)), jnp.int8)
    ks = jnp.asarray(RNG.random((b, s, hkv, 1)) * 0.1, jnp.float32)
    vs = jnp.asarray(RNG.random((b, s, hkv, 1)) * 0.1, jnp.float32)
    lengths = jnp.asarray([300, 123], jnp.int32)
    got = mqa_decode_pallas(
        q, kd, vd, ks, vs, lengths,
        kv_bits=8, sm_scale=1.0 / np.sqrt(d), bs=bs, interpret=True,
    )
    exp = ref.mqa_decode_ref(
        q.reshape(b, h, d), kd, vd, ks, vs, lengths, sm_scale=1.0 / np.sqrt(d)
    )
    np.testing.assert_allclose(
        np.asarray(got.reshape(b, h, d)), np.asarray(exp), atol=3e-3, rtol=3e-3
    )
