"""Data pipeline invariants: determinism, host-slice composition, prefetch."""
import numpy as np

from repro.data.pipeline import DataConfig, iterate, make_batch


def test_deterministic_replay():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=3)
    a = make_batch(cfg, step=17)
    b = make_batch(cfg, step=17)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = make_batch(cfg, step=18)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_host_slices_compose_to_global():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=0)
    full = np.asarray(make_batch(cfg, 5)["tokens"])
    parts = [
        np.asarray(make_batch(cfg, 5, host_slice=(i, i + 2))["tokens"])
        for i in range(0, 8, 2)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)
    # a DIFFERENT host topology composes to the same global batch
    parts4 = [
        np.asarray(make_batch(cfg, 5, host_slice=(i, i + 4))["tokens"])
        for i in range(0, 8, 4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts4, axis=0), full)


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=2)
    b = make_batch(cfg, 0)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    assert (np.asarray(b["tokens"]) < 100).all()


def test_learnable_structure_exists():
    """The injected bigram rule holds on a fixed fraction of positions."""
    cfg = DataConfig(vocab=1000, seq_len=300, global_batch=4)
    b = make_batch(cfg, 0)
    toks = np.asarray(b["tokens"])
    pos = np.arange(1, 300)
    rule = pos[(pos % 3) == 2]
    hits = np.mean(toks[:, rule] == (toks[:, rule - 1] + 1) % 1000)
    assert hits > 0.95


def test_prefetch_iterator():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    it = iterate(cfg, start_step=0)
    b0 = next(it)
    b1 = next(it)
    np.testing.assert_array_equal(
        np.asarray(b0["tokens"]), np.asarray(make_batch(cfg, 0)["tokens"])
    )
    np.testing.assert_array_equal(
        np.asarray(b1["tokens"]), np.asarray(make_batch(cfg, 1)["tokens"])
    )
