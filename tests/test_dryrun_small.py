"""The dry-run machinery end-to-end on a small mesh (subprocess, 16 devices):
lower + compile + memory/cost/collective extraction for reduced configs.

The full 512-device production sweep runs via `python -m repro.launch.dryrun
--all` (results recorded in EXPERIMENTS.md); this test keeps the machinery
honest in CI time."""
import pytest


@pytest.mark.parametrize(
    "arch,kind",
    [("llama3.2-3b", "train"), ("yi-9b", "decode"), ("mamba2-130m", "decode"),
     ("kimi-k2-1t-a32b", "train")],
)
def test_small_mesh_cell(subproc, arch, kind):
    subproc(
        f"""
        import dataclasses, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.shapes import ShapeSpec
        from repro.distributed import sharding as sh
        from repro.launch import dryrun as dr

        mesh = jax.make_mesh((4, 4), ("data", "model"))
        arch = dataclasses.replace(
            get_config("{arch}").reduced(), remat="none",
        )
        shape = ShapeSpec("t", seq_len=128, global_batch=8, kind="{kind}")
        lowered = dr.build_lowered(arch, shape, mesh)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        assert cost.get("flops", 0) > 0
        hlo = compiled.as_text()
        coll = dr.collective_bytes_from_hlo(hlo)
        total = sum(coll.values())
        print("collectives:", coll)
        assert total > 0, "sharded model must communicate"
        print("small dryrun OK", "{arch}", "{kind}")
        """,
        n_devices=16,
        timeout=900,
    )


def test_collective_parser_units():
    from repro.launch.dryrun import collective_bytes_from_hlo

    hlo = """
  %x = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %p), replica_groups={}
  %y = bf16[64]{0} all-gather(bf16[32]{0} %q), dimensions={0}
  %z = f32[8,8]{1,0} add(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
  %w = (s8[1024]{0}, s8[1024]{0}) all-to-all(s8[1024]{0} %c, s8[1024]{0} %d)
"""
    out = collective_bytes_from_hlo(hlo)
    # output-operand bytes per op (operands inside parens are not re-counted)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 64 * 2
    assert out["all-to-all"] == 2 * 1024
    assert out["reduce-scatter"] == 0
