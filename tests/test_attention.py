"""Flash attention (scan form) and decode attention vs naive softmax oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import decode_attention, flash_attention

RNG = np.random.default_rng(0)


def naive_attention(q, k, v, causal=True, window=None, q_offset=0):
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    scores /= np.sqrt(d)
    qp = q_offset + jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= (qp - kp) < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv).astype(q.dtype)


def _case(b, sq, sk, hkv, g, d):
    q = jnp.asarray(RNG.normal(size=(b, sq, hkv * g, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, sk, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, sk, hkv, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [None, 16, 64])
@pytest.mark.parametrize("b,s,hkv,g,d", [(2, 128, 2, 2, 32), (1, 200, 1, 4, 64)])
def test_flash_vs_naive(window, b, s, hkv, g, d):
    q, k, v = _case(b, s, s, hkv, g, d)
    got = flash_attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
    exp = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_flash_dynamic_window():
    """gemma3-style: window passed as a traced scalar."""
    q, k, v = _case(1, 128, 128, 2, 2, 32)

    @jax.jit
    def f(win):
        return flash_attention(q, k, v, causal=True, window=win, block_q=64, block_k=64)

    got = f(jnp.asarray(16, jnp.int32))
    exp = naive_attention(q, k, v, causal=True, window=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_flash_q_offset():
    """Continuation chunk: queries at absolute positions 64.."""
    q, k, v = _case(1, 64, 128, 2, 2, 32)
    k2, v2 = jnp.tile(k, (1, 2, 1, 1)), jnp.tile(v, (1, 2, 1, 1))
    got = flash_attention(q, k2, v2, causal=True, q_offset=64, block_q=32, block_k=32)
    exp = naive_attention(q, k2, v2, causal=True, q_offset=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [None, 32])
def test_decode_vs_naive(window):
    b, s, hkv, g, d = 2, 96, 2, 3, 32
    q, k, v = _case(b, 1, 8, hkv, g, d)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    length = 80
    got = decode_attention(q, k, v, length, window=window)
    # oracle: a 1-query attention with q at position length-1
    kk = k[:, :length]
    vv = v[:, :length]
    exp = naive_attention(q, kk, vv, causal=True, window=window, q_offset=length - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_decode_quantized_scales():
    b, s, hkv, g, d = 1, 64, 2, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, 1, hkv * g, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    from repro.kernels.ops import quantize_kv

    kd, ks = quantize_kv(k, 8)
    vd, vs = quantize_kv(v, 8)
    got = decode_attention(q, kd, vd, s, k_scale=ks, v_scale=vs)
    exp = decode_attention(q, k, v, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-2, rtol=2e-2)
