"""mpconv (multi-precision conv through the matmul core) vs lax.conv oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _quantized_oracle(x, w, ws, w_bits, stride, pad):
    """Conv with the same weight quantization the op applies."""
    qmax = 2 ** (w_bits - 1) - 1
    wq = ws.reshape(1, 1, 1, -1) * jnp.round(
        jnp.clip(w / ws.reshape(1, 1, 1, -1), -qmax - 1, qmax)
    )
    return ref.mpconv_ref(x, wq, stride=stride, padding=pad)


@pytest.mark.parametrize("w_bits", [4, 8])
@pytest.mark.parametrize("dataflow", ["ff", "cf", "auto"])
@pytest.mark.parametrize("ksize,stride,pad", [(1, 1, 0), (3, 1, 1), (5, 1, 2), (3, 2, 1)])
def test_mpconv_sweep(w_bits, dataflow, ksize, stride, pad):
    n, h, w_, ci, co = 2, 10, 10, 12, 24
    x = jnp.asarray(RNG.normal(size=(n, h, w_, ci)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(ksize, ksize, ci, co)), jnp.float32)
    wd, ws = ops.conv_pack_weights(w, w_bits)
    got = ops.mpconv(
        x, wd, ws, w_bits=w_bits, ksize=ksize, stride=stride, padding=pad,
        dataflow=dataflow,
    )
    exp = _quantized_oracle(x, w, ws, w_bits, stride, pad)
    assert got.shape == exp.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-2, rtol=2e-2)


def test_ff_and_cf_agree():
    n, h, w_, ci, co = 1, 8, 8, 8, 16
    x = jnp.asarray(RNG.normal(size=(n, h, w_, ci)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(3, 3, ci, co)), jnp.float32)
    wd, ws = ops.conv_pack_weights(w, 8)
    a = ops.mpconv(x, wd, ws, w_bits=8, ksize=3, padding=1, dataflow="ff")
    b = ops.mpconv(x, wd, ws, w_bits=8, ksize=3, padding=1, dataflow="cf")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_cnn_zoo_tiny_network_runs():
    """End-to-end: a few GoogLeNet-shaped layers through the mixed selector."""
    from repro.core.perfmodel import select_dataflow
    from repro.core.dataflow import ConvLayer
    from repro.core.isa import Dataflow
    from repro.core.precision import Precision

    # conv1x1 should pick CF, conv5x5 should pick FF under the fitted model
    l1 = ConvLayer("1x1", 192, 64, 1, 28, 28, 1, 0)
    l5 = ConvLayer("5x5", 192, 64, 5, 28, 28, 1, 2)
    d1 = select_dataflow(l1, Precision.INT16)
    d5 = select_dataflow(l5, Precision.INT16)
    assert d1 in (Dataflow.FF, Dataflow.CF)
    assert d5 in (Dataflow.FF, Dataflow.CF)
