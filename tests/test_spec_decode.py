"""Speculative decoding (W4/W8 draft -> exact target verify): token
equivalence with plain greedy decode across precisions (including under
forced preemption and prefix-cache warm starts), KV truncate/rollback
refcount + CoW invariants, and regression tests for the stop-token and
oversized-context-livelock fixes."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import (
    PagedKVCache,
    PrecisionParams,
    PrefixCache,
    RequestState,
    SamplingParams,
    ServeEngine,
    ServeRequest,
    block_hashes,
)


def _cfg(**kw):
    base = dataclasses.replace(
        get_config("llama3.2-3b").reduced(),
        n_layers=2, d_model=64, d_ff=128, vocab=256, n_heads=4, n_kv_heads=2,
        head_dim=16, serve_kv_bits=8,
    )
    return dataclasses.replace(base, **kw)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, prompts, new_tokens=8, spec_k=0, num_pages=64,
         prefill_chunk=16, enable_prefix_cache=True, eos_id=None,
         stop_tokens=(), **precision_kw):
    eng = ServeEngine(
        cfg, params, max_slots=len(prompts), num_pages=num_pages, page_size=4,
        prefill_chunk=prefill_chunk, enable_prefix_cache=enable_prefix_cache,
        spec_k=spec_k,
    )
    sampling = SamplingParams(max_new_tokens=new_tokens, eos_id=eos_id,
                              stop_tokens=stop_tokens)
    precision = PrecisionParams(**precision_kw)
    reqs = [eng.submit(p, sampling, precision) for p in prompts]
    eng.run()
    return eng, reqs


# ------------------------------------------------ spec == plain equivalence
@pytest.mark.parametrize("kv_bits", [4, 8, 16])
def test_spec_equals_plain_greedy(setup, kv_bits):
    """Speculative decode must emit token-for-token the plain greedy stream
    for every kv precision (greedy draft + greedy verify => exact accept)."""
    cfg, params = setup
    w_bits = 16 if kv_bits == 16 else 8
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 9).astype(np.int32) for _ in range(3)]
    _, plain = _run(cfg, params, prompts, w_bits=w_bits, kv_bits=kv_bits)
    eng, spec = _run(cfg, params, prompts, spec_k=3, w_bits=w_bits,
                     kv_bits=kv_bits, draft_bits=4)
    assert [r.out_tokens for r in plain] == [r.out_tokens for r in spec]
    assert all(len(r.out_tokens) == 8 for r in spec)  # budget exactly honored
    assert eng.stats.spec_rounds > 0
    assert eng.stats.spec_draft_tokens >= eng.stats.spec_accepted_tokens >= 0


@pytest.mark.parametrize("w_bits,draft_bits", [(4, 4), (8, 8), (16, 8)])
def test_spec_equals_plain_across_weight_precisions(setup, w_bits, draft_bits):
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, 7).astype(np.int32) for _ in range(2)]
    kv = 16 if w_bits == 16 else 8
    _, plain = _run(cfg, params, prompts, w_bits=w_bits, kv_bits=kv)
    eng, spec = _run(cfg, params, prompts, spec_k=4, w_bits=w_bits,
                     kv_bits=kv, draft_bits=draft_bits)
    assert [r.out_tokens for r in plain] == [r.out_tokens for r in spec]
    # a same-precision draft is the target: every draft must be accepted
    if draft_bits == w_bits:
        assert eng.stats.spec_accept_rate == 1.0


def test_spec_mixed_precision_stream(setup):
    """W4/W8/bf16 spec requests in one engine still group, decode in the
    same steps, and match their single-precision plain runs."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32) for _ in range(4)]
    mix = [(4, 8), (8, 8), (16, 16), (8, 8)]
    eng = ServeEngine(cfg, params, max_slots=4, num_pages=64, page_size=4,
                      spec_k=2, draft_bits=4)
    spec = [
        eng.submit(p, SamplingParams(max_new_tokens=6), PrecisionParams(w_bits=w, kv_bits=k))
        for p, (w, k) in zip(prompts, mix)
    ]
    eng.run()
    for i, (w, k) in enumerate(mix):
        _, (plain,) = _run(cfg, params, [prompts[i]], new_tokens=6,
                           w_bits=w, kv_bits=k)
        assert spec[i].out_tokens == plain.out_tokens, f"request {i} (w{w}kv{k})"
    assert eng.stats.mixed_precision_steps > 0


def test_spec_under_forced_preemption(setup):
    """Pool too small for the batch: spec requests get preempted and
    recompute, and still emit exactly the plain greedy stream."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, 10).astype(np.int32) for _ in range(3)]
    _, plain = _run(cfg, params, prompts, new_tokens=8, num_pages=10,
                    w_bits=8, kv_bits=8)
    eng, spec = _run(cfg, params, prompts, new_tokens=8, num_pages=10,
                     spec_k=3, w_bits=8, kv_bits=8)
    assert eng.stats.preemptions > 0
    assert [r.out_tokens for r in plain] == [r.out_tokens for r in spec]
    # every page is reclaimable again after the run
    cache = eng.cache_for(8)
    assert cache.num_allocatable == 10
    assert not cache._tables


def test_spec_with_warm_prefix_start(setup):
    """A spec request admitted onto cached prefix pages (warm start) must
    match the identical request on a cold spec-off engine."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    sys_prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab, 5).astype(np.int32) for _ in range(2)]
    prompts = [np.concatenate([sys_prompt, t]) for t in tails]

    eng = ServeEngine(cfg, params, max_slots=2, num_pages=64, page_size=4,
                      prefill_chunk=8, spec_k=3)
    a = eng.submit(prompts[0], SamplingParams(max_new_tokens=6), PrecisionParams(w_bits=8, kv_bits=8))
    eng.run()
    b = eng.submit(prompts[1], SamplingParams(max_new_tokens=6), PrecisionParams(w_bits=8, kv_bits=8))
    eng.run()
    assert eng.stats.prefix_hit_tokens >= 12  # b adopted the shared prefix

    for i, warm in enumerate((a, b)):
        _, (cold,) = _run(cfg, params, [prompts[i]], new_tokens=6,
                          enable_prefix_cache=False, w_bits=8, kv_bits=8)
        assert warm.out_tokens == cold.out_tokens, f"request {i}"


def test_spec_window_clips_at_token_budget(setup):
    """max_new_tokens not a multiple of the round size: the last window is
    clipped mid-round and the budget is honored exactly."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 6).astype(np.int32)]
    for budget in (1, 2, 5, 7):
        _, plain = _run(cfg, params, prompts, new_tokens=budget,
                        w_bits=8, kv_bits=8)
        _, spec = _run(cfg, params, prompts, new_tokens=budget, spec_k=3,
                       w_bits=8, kv_bits=8, draft_bits=8)
        assert len(spec[0].out_tokens) == budget
        assert spec[0].out_tokens == plain[0].out_tokens


# ------------------------------------------------- truncate / rollback pool
def _pool(num_pages=8, page_size=4, kv_bits=8):
    cfg = _cfg()
    return PagedKVCache(cfg, num_pages=num_pages, page_size=page_size,
                        kv_bits=kv_bits)


def test_truncate_drops_tail_pages_only():
    pool = _pool()
    pages = list(pool.allocate(0, 4))
    dropped = pool.truncate(0, 6)  # 6 tokens -> 2 pages kept
    assert dropped == pages[2:]
    assert pool.table(0) == pages[:2]
    assert pool.num_free == 6
    # truncating inside the covered range is a no-op
    assert pool.truncate(0, 5) == []
    # LIFO: a dropped page is the next one handed out (tail decref'd first,
    # so the former slot-2 page sits on top of the free list)
    assert pool.extend(0, 1) == [pages[2]]
    assert pool.capacity_tokens(0) == 12


def test_truncate_shared_pages_decref_not_free():
    """A shared tail page loses only this request's reference; the other
    holder keeps it alive and its payload is untouched."""
    pool = _pool()
    owner = pool.allocate(0, 3)
    pool.allocate(1, 3, prefix_pages=tuple(owner))  # full adoption
    assert pool.refcount(owner[2]) == 2
    dropped = pool.truncate(1, 4)  # rid 1 keeps only the first page
    assert dropped == owner[1:]
    assert pool.refcount(owner[1]) == 1 and pool.refcount(owner[2]) == 1
    assert pool.num_free == 5  # nothing actually freed: rid 0 still holds all
    assert pool.table(0) == owner
    pool.free(0)
    pool.free(1)
    assert pool.num_free == 8


def test_truncate_after_cow_fork_leaves_original():
    """Truncating a forked table drops the private copy back to the pool
    while the original shared page (and its refcount) is untouched."""
    pool = _pool()
    orig = pool.allocate(0, 2)
    pool.allocate(1, 2, prefix_pages=tuple(orig))
    forked = pool.fork_page(1, 1)
    assert pool.refcount(orig[1]) == 1 and pool.refcount(forked) == 1
    dropped = pool.truncate(1, 4)  # drop the fork, keep the shared head
    assert dropped == [forked]
    assert pool.refcount(forked) == 0 and forked in pool._free
    assert pool.refcount(orig[1]) == 1  # rid 0's reference survives
    assert pool.table(0) == orig


def test_truncate_forgotten_registered_page_returns_to_pool():
    """forget_pages before truncate: a registered tail page whose content a
    rejected verify window overwrote must neither serve hits nor leak."""
    pool = _pool()
    pc = PrefixCache(pool)
    hashes = block_hashes(np.arange(8, dtype=np.int32), 4)
    pages = pool.allocate(0, 2)
    pc.register(hashes, pages)
    pc.forget_pages([pages[1]])
    assert pc.match(hashes) == pages[:1]  # tail block no longer matchable
    dropped = pool.truncate(0, 4)
    assert dropped == [pages[1]]
    # forgotten page went straight to the free list (not retained)
    assert pages[1] in pool._free and pc.num_retained == 0
    # a *retained* forgotten page is handed back immediately
    pool.free(0)
    assert pc.num_retained == 1  # pages[0] still registered -> retained
    pc.forget_pages([pages[0]])
    assert pc.num_retained == 0 and pool.num_free == 8


def test_spec_rollback_truncates_tail_pages(setup):
    """After a spec run every page beyond each live request's cache_len has
    been rolled back: finished engines return the whole pool."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, 9).astype(np.int32) for _ in range(2)]
    eng, reqs = _run(cfg, params, prompts, new_tokens=6, spec_k=3,
                     num_pages=32, w_bits=8, kv_bits=8, draft_bits=8)
    cache = eng.cache_for(8)
    assert cache.num_allocatable == 32
    assert not cache._tables and not cache._refcount


# --------------------------------------------------- stop-token regressions
def test_eos_terminates_decode(setup):
    """Pre-fix the engine always burned max_new_tokens; with eos_id set it
    must stop the moment the stop token is emitted (token kept)."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)]
    _, (ref,) = _run(cfg, params, prompts, new_tokens=8, w_bits=8, kv_bits=8)
    eos = ref.out_tokens[3]
    first = ref.out_tokens.index(eos)
    _, (req,) = _run(cfg, params, prompts, new_tokens=8, w_bits=8, kv_bits=8,
                     eos_id=eos)
    assert req.out_tokens == ref.out_tokens[: first + 1]
    assert req.done


def test_eos_terminates_in_prefill(setup):
    """A request whose *first* token is the stop token finishes straight out
    of prefill with exactly one emitted token."""
    cfg, params = setup
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)]
    _, (ref,) = _run(cfg, params, prompts, new_tokens=4, w_bits=8, kv_bits=8)
    _, (req,) = _run(cfg, params, prompts, new_tokens=4, w_bits=8, kv_bits=8,
                     eos_id=ref.out_tokens[0])
    assert req.out_tokens == ref.out_tokens[:1] and req.done


def test_eos_clips_mid_spec_window(setup):
    """The stop token can land anywhere inside an accepted verify window;
    emission must cut right after it and the caches must roll back clean."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)]
    _, (ref,) = _run(cfg, params, prompts, new_tokens=8, w_bits=8, kv_bits=8)
    for pos in (2, 4, 6):
        eos = ref.out_tokens[pos]
        first = ref.out_tokens.index(eos)
        eng, (req,) = _run(cfg, params, prompts, new_tokens=8, spec_k=3,
                           w_bits=8, kv_bits=8, draft_bits=8, eos_id=eos)
        assert req.out_tokens == ref.out_tokens[: first + 1]
        assert req.done
        assert eng.cache_for(8).num_allocatable == 64  # nothing leaked
        # accept stats count only drafts the emission cashed in: every spec
        # round emits its counted accepts + 1 (prefill emits the first token)
        spec_emitted = len(req.out_tokens) - 1
        assert (eng.stats.spec_accepted_tokens
                <= spec_emitted - eng.stats.spec_rounds)


def test_stop_tokens_list(setup):
    cfg, params = setup
    rng = np.random.default_rng(10)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)]
    _, (ref,) = _run(cfg, params, prompts, new_tokens=8, w_bits=8, kv_bits=8)
    stops = (ref.out_tokens[2], ref.out_tokens[5])
    first = min(ref.out_tokens.index(s) for s in stops)
    _, (req,) = _run(cfg, params, prompts, new_tokens=8, w_bits=8, kv_bits=8,
                     stop_tokens=stops)
    assert req.out_tokens == ref.out_tokens[: first + 1]


# ------------------------------------------- oversized-context (livelock) fix
def test_oversized_request_rejected_at_submit(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_slots=1, num_pages=4, page_size=4)
    with pytest.raises(ValueError, match="never fit"):
        eng.submit(np.arange(8, dtype=np.int32), SamplingParams(max_new_tokens=32), PrecisionParams(w_bits=8, kv_bits=8))


def test_oversized_request_fails_at_admission_without_livelock(setup):
    """A too-big request that reaches the queue anyway (submitted behind the
    engine's back) must FAIL with a clear error — pre-fix it would admit,
    outgrow the pool, self-preempt and readmit forever while run() counted
    the admission as progress."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_slots=2, num_pages=4, page_size=4)
    ok = eng.submit(np.arange(4, dtype=np.int32), SamplingParams(max_new_tokens=4), PrecisionParams(w_bits=8, kv_bits=8))
    big = ServeRequest(rid=99, prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=64, w_bits=8, kv_bits=8, arrival=10**6)
    eng._sched.submit(big)
    done = eng.run()  # must terminate
    assert ok.done and len(ok.out_tokens) == 4
    assert big.failed and big.state is RequestState.FAILED
    assert "never fit" in big.error and "pages" in big.error
    assert big in done and eng.stats.failed == 1
    # the pool is clean: the failed request never held pages
    assert eng.cache_for(8).num_allocatable == 4


def test_failed_head_does_not_starve_followers(setup):
    """The FAILED head-of-line request is removed, so younger requests admit
    on the next step instead of being blocked forever."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_slots=2, num_pages=8, page_size=4)
    big = ServeRequest(rid=50, prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=64, w_bits=8, kv_bits=8, arrival=-1)
    eng._sched.submit(big)  # sits at the head of the queue
    ok = eng.submit(np.arange(4, dtype=np.int32), SamplingParams(max_new_tokens=4), PrecisionParams(w_bits=8, kv_bits=8))
    eng.run()
    assert big.failed and ok.done and len(ok.out_tokens) == 4
