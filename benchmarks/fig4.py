"""Fig. 4 reproduction: average area efficiency across VGG16 / ResNet18 /
GoogLeNet / SqueezeNet at 16/8/4-bit (mixed dataflow), SPEED vs Ara."""
from __future__ import annotations

from repro.core.perfmodel import (
    AraModel,
    SpeedModel,
    evaluate_network,
    evaluate_network_ara,
)
from repro.core.precision import Precision
from repro.models.cnn_zoo import BENCHMARK_NETWORKS

PAPER = {"ratio_16": 2.77, "ratio_8": 6.39, "avg4_area_eff": 94.6}


def compute(sm: SpeedModel | None = None, am: AraModel | None = None) -> dict:
    sm, am = sm or SpeedModel(), am or AraModel()
    nets = {k: f() for k, f in BENCHMARK_NETWORKS.items()}
    per_net: dict = {}
    avg = {}
    for bits in (16, 8, 4):
        prec = Precision.from_bits(bits)
        vals = {}
        for name, ls in nets.items():
            s = evaluate_network(ls, prec, "mixed", sm)["area_eff"]
            a = (
                evaluate_network_ara(ls, prec, am)["area_eff"]
                if bits != 4
                else None
            )
            vals[name] = (s, a)
        per_net[bits] = vals
        avg[bits] = (
            sum(v[0] for v in vals.values()) / len(vals),
            sum(v[1] for v in vals.values()) / len(vals) if bits != 4 else None,
        )
    return {"per_net": per_net, "avg": avg}


def rows() -> list[tuple]:
    r = compute()["avg"]
    out = [
        ("fig4_ratio_16b", r[16][0] / r[16][1], PAPER["ratio_16"],
         r[16][0] / r[16][1] / PAPER["ratio_16"] - 1),
        ("fig4_ratio_8b", r[8][0] / r[8][1], PAPER["ratio_8"],
         r[8][0] / r[8][1] / PAPER["ratio_8"] - 1),
        ("fig4_avg4_area_eff", r[4][0], PAPER["avg4_area_eff"],
         r[4][0] / PAPER["avg4_area_eff"] - 1),
    ]
    return out


def main() -> None:
    out = compute()
    print(f"{'metric':<24}{'model':>10}{'paper':>10}{'rel_err':>9}")
    for name, got, paper, err in rows():
        print(f"{name:<24}{got:>10.2f}{paper:>10.2f}{err * 100:>8.1f}%")
    print("\nper-network area efficiency (GOPS/mm^2), SPEED (Ara):")
    for bits, vals in out["per_net"].items():
        row = ", ".join(
            f"{n}: {s:.1f}" + (f" ({a:.1f})" if a else "") for n, (s, a) in vals.items()
        )
        print(f"  {bits:>2}-bit  {row}")


if __name__ == "__main__":
    main()
