"""Kernel micro-benchmarks (CPU walltime of the XLA path + interpret-mode
validation cost; TPU wall-clock comes from the roofline, not this box).

Measures the framework-level effect the paper sells: int4/int8 weights cut
the bytes a serving matmul moves (2x/4x vs bf16), and the quantized KV cache
cuts decode attention traffic."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, iters=5) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def rows() -> list[tuple]:
    rng = np.random.default_rng(0)
    m, k, n = 256, 2048, 2048
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = []
    bytes_bf16 = k * n * 2
    for bits in (8, 4):
        wd, ws = ops.pack_weights(w, bits)
        us = _time(
            lambda xx, dd=wd, ss=ws, b=bits: ops.mpmm(xx, dd, ss, w_bits=b, backend="xla"),
            x,
        )
        wire = wd.size * wd.dtype.itemsize
        out.append((f"mpmm_w{bits}_xla_{m}x{k}x{n}", us, bytes_bf16 / wire))
    # decode attention with quantized KV
    b_, s, hkv, g, d = 4, 2048, 4, 4, 64
    q = jnp.asarray(rng.normal(size=(b_, hkv * g, d)), jnp.float32)
    kv = rng.normal(size=(2, b_, s, hkv, d)).astype(np.float32)
    for bits in (8, 4):
        kd, ks = ops.quantize_kv(jnp.asarray(kv[0]), bits)
        vd, vs = ops.quantize_kv(jnp.asarray(kv[1]), bits)
        lengths = jnp.full((b_,), s, jnp.int32)
        from repro.kernels import ref
        from repro.quant.pack import unpack_int4

        kdu = unpack_int4(kd, -1) if bits == 4 else kd
        vdu = unpack_int4(vd, -1) if bits == 4 else vd
        us = _time(
            lambda qq: ref.mqa_decode_ref(qq, kdu, vdu, ks, vs, lengths, sm_scale=0.125),
            q,
        )
        payload_ratio = (2 * b_ * s * hkv * d * 2) / (kd.size + vd.size)
        out.append((f"decode_kv{bits}_s{s}", us, payload_ratio))
    return out


def main() -> None:
    print("name,us_per_call,derived(bytes_saved_ratio)")
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived:.2f}")


if __name__ == "__main__":
    main()
