"""Kernel micro-benchmarks (CPU walltime of the XLA path + interpret-mode
validation cost; TPU wall-clock comes from the roofline, not this box).

Measures the framework-level effect the paper sells: int4/int8 weights cut
the bytes a serving matmul moves (2x/4x vs bf16), the quantized KV cache
cuts decode attention traffic, and the paged decode kernel cuts per-token
traffic from table *capacity* to actual *occupancy* (no full-cache gather).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, iters=5) -> float:
    """Best-of-N walltime in us: the min is the noise-robust estimator on a
    shared CPU box (scheduler hiccups only ever make a run slower)."""
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def mpmm_rows(smoke: bool = False) -> list[tuple]:
    rng = np.random.default_rng(0)
    m, k, n = (64, 256, 256) if smoke else (256, 2048, 2048)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    out = []
    bytes_bf16 = k * n * 2
    for bits in (8, 4):
        wd, ws = ops.pack_weights(w, bits)
        us = _time(
            lambda xx, dd=wd, ss=ws, b=bits: ops.mpmm(xx, dd, ss, w_bits=b, backend="xla"),
            x,
        )
        wire = wd.size * wd.dtype.itemsize
        out.append((f"mpmm_w{bits}_xla_{m}x{k}x{n}", us, bytes_bf16 / wire))
    return out


def decode_kv_rows(smoke: bool = False) -> list[tuple]:
    rng = np.random.default_rng(0)
    b_, s, hkv, g, d = (2, 512, 2, 2, 32) if smoke else (4, 2048, 4, 4, 64)
    q = jnp.asarray(rng.normal(size=(b_, hkv * g, d)), jnp.float32)
    kv = rng.normal(size=(2, b_, s, hkv, d)).astype(np.float32)
    out = []
    for bits in (8, 4):
        kd, ks = ops.quantize_kv(jnp.asarray(kv[0]), bits)
        vd, vs = ops.quantize_kv(jnp.asarray(kv[1]), bits)
        lengths = jnp.full((b_,), s, jnp.int32)
        from repro.kernels import ref
        from repro.quant.pack import unpack_int4

        kdu = unpack_int4(kd, -1) if bits == 4 else kd
        vdu = unpack_int4(vd, -1) if bits == 4 else vd
        us = _time(
            lambda qq: ref.mqa_decode_ref(qq, kdu, vdu, ks, vs, lengths, sm_scale=0.125),
            q,
        )
        payload_ratio = (2 * b_ * s * hkv * d * 2) / (kd.size + vd.size)
        out.append((f"decode_kv{bits}_s{s}", us, payload_ratio))
    return out


def paged_decode_rows(smoke: bool = False) -> list[tuple]:
    """Paged kernel vs the old full-table gather, across pool occupancy.

    The gather path copies every table slot into a contiguous [B, S, ...]
    view before attending (cost ∝ table capacity); the paged path walks page
    tables in place (cost ∝ occupied length).  ``derived`` reports effective
    GB/s = bytes the path *actually had to touch* (occupied cache positions,
    K+V payload+scales, once) / walltime — so at low occupancy the gather
    path's useless capacity traffic shows up as a collapsing goodput.

    At ~full occupancy the XLA fallback's sequential slot scan can lose to
    one dense gather on CPU (small per-page gathers vectorize worse); that
    overhead is an artifact of the fallback, not the contract — the compiled
    Pallas kernel pays per-page DMA either way and only *skips* dead slots.
    """
    from repro.kernels import ref

    rng = np.random.default_rng(1)
    if smoke:
        b_, hkv, g, d, ps, w = 2, 2, 2, 32, 16, 4
    else:
        b_, hkv, g, d, ps, w = 4, 2, 4, 64, 64, 16
    s = w * ps
    n_pages = b_ * w
    kv_bits = 8
    q = jnp.asarray(rng.normal(size=(b_, hkv * g, d)), jnp.float32)
    kp = jnp.asarray(rng.integers(-127, 128, (1, n_pages, ps, hkv, d)), jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, (1, n_pages, ps, hkv, d)), jnp.int8)
    ks = jnp.asarray(rng.random((1, n_pages, ps, hkv, 1)) * 0.1, jnp.float32)
    vs = jnp.asarray(rng.random((1, n_pages, ps, hkv, 1)) * 0.1, jnp.float32)
    nk = jnp.asarray(rng.integers(-127, 128, (b_, hkv, d)), jnp.int8)
    nv = jnp.asarray(rng.integers(-127, 128, (b_, hkv, d)), jnp.int8)
    nks = jnp.asarray(rng.random((b_, hkv, 1)) * 0.1, jnp.float32)
    nvs = jnp.asarray(rng.random((b_, hkv, 1)) * 0.1, jnp.float32)
    tables = jnp.asarray(
        rng.permutation(n_pages).reshape(b_, w).astype(np.int32)
    )
    rows_idx = jnp.arange(b_)
    sm = 1.0 / float(np.sqrt(d))


    @jax.jit
    def gather_path(lengths):
        # the old serve path: copy every table slot, insert, attend densely
        kd = ref.gather_pages(kp, tables)[0].at[rows_idx, lengths].set(nk)
        vd = ref.gather_pages(vp, tables)[0].at[rows_idx, lengths].set(nv)
        ksd = ref.gather_pages(ks, tables)[0].at[rows_idx, lengths].set(nks)
        vsd = ref.gather_pages(vs, tables)[0].at[rows_idx, lengths].set(nvs)
        return ref.mqa_decode_ref(q, kd, vd, ksd, vsd, lengths + 1, sm_scale=sm)

    def paged_path(lengths):
        return ops.paged_mqa_decode(
            q, kp, vp, ks, vs, tables, lengths, 0, nk, nv, nks, nvs,
            kv_bits=kv_bits, backend="xla",
        )

    out = []
    tok_bytes = hkv * (2 * d + 8)  # K+V payload + two f32 scales per position
    for occ in (1.0, 0.5, 0.25):
        ln = max(int(s * occ) - 1, 1)
        lengths = jnp.full((b_,), ln, jnp.int32)
        useful = b_ * (ln + 1) * tok_bytes  # bytes any path must touch
        for name, fn in (("gather", gather_path), ("paged", paged_path)):
            us = _time(fn, lengths, iters=20)  # shared box: noisy, min-of-20
            gbps = useful / (us * 1e-6) / 1e9
            out.append((f"decode_{name}_s{s}_occ{int(occ * 100)}", us, gbps))
    return out


def rows(smoke: bool = False) -> list[tuple]:
    return mpmm_rows(smoke) + decode_kv_rows(smoke) + paged_decode_rows(smoke)


def main() -> None:
    print("name,us_per_call,derived(ratio_or_eff_GBps)")
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived:.2f}")


if __name__ == "__main__":
    main()
