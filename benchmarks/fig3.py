"""Fig. 3 reproduction: GoogLeNet layer-wise area efficiency under FF-only /
CF-only / mixed dataflows at 16-bit, vs Ara, with the per-layer strategy the
mixed selector chose (the paper's annotation)."""
from __future__ import annotations

from collections import Counter

from repro.core.perfmodel import (
    AraModel,
    SpeedModel,
    evaluate_network,
    evaluate_network_ara,
    select_dataflow,
)
from repro.core.precision import Precision
from repro.models.cnn_zoo import googlenet_layers

PAPER = {
    "mixed_over_ff": 1.88,
    "mixed_over_cf": 1.38,
    "ff_over_ara": 1.87,
    "cf_over_ara": 2.55,
    "mixed_over_ara": 3.53,
}


def compute(sm: SpeedModel | None = None, am: AraModel | None = None) -> dict:
    sm, am = sm or SpeedModel(), am or AraModel()
    gl = googlenet_layers()
    prec = Precision.INT16
    res = {s: evaluate_network(gl, prec, s, sm) for s in ("ff", "cf", "mixed")}
    ara = evaluate_network_ara(gl, prec, am)
    ratios = {
        "mixed_over_ff": res["mixed"]["area_eff"] / res["ff"]["area_eff"],
        "mixed_over_cf": res["mixed"]["area_eff"] / res["cf"]["area_eff"],
        "ff_over_ara": res["ff"]["area_eff"] / ara["area_eff"],
        "cf_over_ara": res["cf"]["area_eff"] / ara["area_eff"],
        "mixed_over_ara": res["mixed"]["area_eff"] / ara["area_eff"],
    }
    decisions = [(l, select_dataflow(l, prec, sm)) for l in gl]
    by_kernel: dict[int, Counter] = {}
    for l, d in decisions:
        by_kernel.setdefault(l.k, Counter())[d.name] += 1
    return {"ratios": ratios, "per_layer": decisions, "by_kernel": by_kernel,
            "nets": res, "ara": ara}


def rows() -> list[tuple]:
    r = compute()["ratios"]
    return [(f"fig3_{k}", r[k], PAPER[k], r[k] / PAPER[k] - 1) for k in PAPER]


def main() -> None:
    out = compute()
    print(f"{'metric':<24}{'model':>10}{'paper':>10}{'rel_err':>9}")
    for name, got, paper, err in rows():
        print(f"{name:<24}{got:>10.2f}{paper:>10.2f}{err * 100:>8.1f}%")
    print("\nmixed-strategy selection by kernel size (paper: CF for 1x1, FF else):")
    for k, cnt in sorted(out["by_kernel"].items()):
        print(f"  conv{k}x{k}: {dict(cnt)}")
    print("\nlayer-wise area efficiency (GOPS/mm^2, 16-bit, mixed):")
    sm = SpeedModel()
    for l, d in out["per_layer"][:10]:
        from repro.core.perfmodel import evaluate_layer

        p = evaluate_layer(l, Precision.INT16, "mixed", sm)
        print(f"  {l.name:<22} k{l.k} {d.name:<3} {p.area_eff:7.2f}")
    print("  ...")


if __name__ == "__main__":
    main()
