"""Serving-engine throughput sweep: tokens/s vs batch size vs precision mix.

Continuous-batching decode throughput for the multi-precision engine on a
tiny CPU-sized model — the point is the *shape* of the curves (occupancy
scaling, W4 vs W8 grouping overhead), not absolute CPU numbers; real-TPU
serving throughput comes from the roofline path.

Importable: ``rows()`` yields (name, decode_tok_per_s, note) tuples, the
same contract as the other benchmark sections.
"""
from __future__ import annotations

import dataclasses
import functools

BATCH_SIZES = (1, 4, 16)
MIXES = {
    "w8": [8],
    "w4": [4],
    "w4+w8": [4, 8],
}
PROMPT_LEN = 8
NEW_TOKENS = 8


@functools.lru_cache(maxsize=1)
def _setup():
    import jax

    from repro.configs import get_config
    from repro.models import transformer as model_lib

    cfg = dataclasses.replace(
        get_config("yi-9b").reduced(),
        n_layers=2, d_model=128, d_ff=256, n_heads=4, n_kv_heads=2,
        head_dim=32, vocab=1024,
    )
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run_one(batch_size: int, mix: list[int]) -> tuple[float, float]:
    import numpy as np

    from repro.serve import ServeEngine

    cfg, params = _setup()
    page_size = 8
    pages_per_slot = -(-(PROMPT_LEN + NEW_TOKENS) // page_size)
    engine = ServeEngine(
        cfg, params,
        max_slots=batch_size,
        num_pages=batch_size * pages_per_slot,
        page_size=page_size,
    )
    rng = np.random.default_rng(0)
    for i in range(batch_size):
        engine.submit(
            rng.integers(0, cfg.vocab, PROMPT_LEN).astype(np.int32),
            NEW_TOKENS,
            w_bits=mix[i % len(mix)],
            kv_bits=8,
        )
    engine.run()
    s = engine.stats
    return s.decode_tok_per_s, s.mean_batch_occupancy


def rows():
    """(name, decode_tok_per_s, mean_batch_occupancy) per configuration."""
    out = []
    for mix_name, mix in MIXES.items():
        for bsz in BATCH_SIZES:
            tok_s, occ = _run_one(bsz, mix)
            out.append((f"serve_{mix_name}_b{bsz}", tok_s, occ))
    return out


if __name__ == "__main__":
    print("name,decode_tok_per_s,mean_batch_occupancy")
    for name, tok_s, occ in rows():
        print(f"{name},{tok_s:.1f},{occ:.2f}")
