"""Serving-engine latency/throughput sweep: tokens/s, TTFT and per-token
percentiles vs batch size vs precision mix, the shared-system-prompt
prefix-cache workload (cold vs warm TTFT), and the speculative-decoding
workload (spec-on vs spec-off tok/s + draft accept rate).

Continuous-batching numbers for the multi-precision engine on a tiny
CPU-sized model — the point is the *shape* of the curves (occupancy scaling,
W4 vs W8 grouping overhead, warm-prefix TTFT collapse, spec-round call
fusion), not absolute CPU numbers; real-TPU serving throughput comes from
the roofline path.

Importable: ``rows()`` yields per-configuration dicts,
``shared_prefix_stats()`` measures cold vs warm prefix-cache TTFT,
``spec_decode_stats()`` measures spec-on vs spec-off decode throughput, and
``sampling_stats()`` measures the sampled workload (greedy vs temperature-0.8
tok/s, fixed-seed reproducibility, spec-on sampled accept rate)
(all best-of-N — this box's walltimes swing run to run).
"""
from __future__ import annotations

import dataclasses
import functools

BATCH_SIZES = (1, 4, 16)
MIXES = {
    "w8": [8],
    "w4": [4],
    "w4+w8": [4, 8],
}
PROMPT_LEN = 8
NEW_TOKENS = 8

# shared-system-prompt workload: 96 of 128 prompt tokens shared (75% share)
SHARED_PREFIX_LEN = 96
SHARED_TAIL_LEN = 32
SHARED_CHUNK = 32

# speculative-decoding workload: synthetic-repetition prompts (a short motif
# tiled across the prompt) decoded at bf16 with a W8 draft — a high-fidelity
# draft whose argmax tracks the target's, so acceptance stays high and the
# round fusion (k drafts + verify in ONE dispatch vs k+1 dispatches) shows
SPEC_K = 3
SPEC_W_BITS = 16
SPEC_DRAFT_BITS = 8
SPEC_BATCH = 4
SPEC_PROMPT_LEN = 16
SPEC_NEW_TOKENS = 32


@functools.lru_cache(maxsize=1)
def _setup():
    import jax

    from repro.configs import get_config
    from repro.models import transformer as model_lib

    cfg = dataclasses.replace(
        get_config("yi-9b").reduced(),
        n_layers=2, d_model=128, d_ff=256, n_heads=4, n_kv_heads=2,
        head_dim=32, vocab=1024,
    )
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _percentile_ms(samples, q) -> float:
    import numpy as np

    if not samples:
        return float("nan")
    return float(np.percentile(np.asarray(samples), q) * 1e3)


def _run_one(batch_size: int, mix: list[int]) -> dict:
    import numpy as np

    from repro.serve import PrecisionParams, SamplingParams, ServeEngine

    cfg, params = _setup()
    page_size = 8
    pages_per_slot = -(-(PROMPT_LEN + NEW_TOKENS) // page_size)
    engine = ServeEngine(
        cfg, params,
        max_slots=batch_size,
        num_pages=batch_size * pages_per_slot,
        page_size=page_size,
    )
    rng = np.random.default_rng(0)
    for i in range(batch_size):
        engine.submit(rng.integers(0, cfg.vocab, PROMPT_LEN).astype(np.int32), SamplingParams(max_new_tokens=NEW_TOKENS), PrecisionParams(w_bits=mix[i % len(mix)], kv_bits=8))
    engine.run()
    s = engine.stats
    return {
        "decode_tok_per_s": s.decode_tok_per_s,
        "ttft_ms_p50": _percentile_ms(s.ttfts, 50),
        "tok_ms_p50": _percentile_ms(s.decode_call_s, 50),
        "tok_ms_p99": _percentile_ms(s.decode_call_s, 99),
        "occupancy": s.mean_batch_occupancy,
    }


def rows():
    """One dict per configuration: throughput, TTFT p50, per-token p50/p99
    latency (batched decode-call walltime), mean occupancy."""
    out = []
    for mix_name, mix in MIXES.items():
        for bsz in BATCH_SIZES:
            out.append((f"serve_{mix_name}_b{bsz}", _run_one(bsz, mix)))
    return out


def _shared_prefix_iter(shared, tails, w_bits=8, kv_bits=8):
    """One cold-then-warm engine pass; returns (cold_ttft, warm_ttfts, eng)."""
    import numpy as np

    from repro.serve import PrecisionParams, SamplingParams, ServeEngine

    cfg, params = _setup()
    page_size = 8
    total = SHARED_PREFIX_LEN + SHARED_TAIL_LEN + NEW_TOKENS
    engine = ServeEngine(
        cfg, params,
        max_slots=2,
        num_pages=(len(tails) + 1) * -(-total // page_size),
        page_size=page_size,
        prefill_chunk=SHARED_CHUNK,
    )
    # pre-touch per-engine lazy setup (weight quantization, pool allocation)
    # so the cold request's TTFT measures prefill cost, not engine warmup —
    # otherwise the cold/warm ratio overstates the prefix-cache win
    engine.params_for(w_bits)
    engine.cache_for(kv_bits)
    cold = engine.submit(np.concatenate([shared, tails[0]]), SamplingParams(max_new_tokens=NEW_TOKENS), PrecisionParams(w_bits=w_bits, kv_bits=kv_bits))
    engine.run()
    warm = []
    for tail in tails[1:]:
        r = engine.submit(np.concatenate([shared, tail]), SamplingParams(max_new_tokens=NEW_TOKENS), PrecisionParams(w_bits=w_bits, kv_bits=kv_bits))
        engine.run()
        warm.append(r.ttft)
    return cold.ttft, warm, engine


def shared_prefix_stats(n_iters: int = 5) -> dict:
    """Cold vs warm prefix-cache TTFT on the shared-system-prompt workload.

    Warm requests share SHARED_PREFIX_LEN of their prompt with an earlier
    request; their prefill skips the cached blocks and computes only the
    tail.  min-of-N over fresh engines (first pass warms jit caches, which
    are keyed on shapes and shared across engine instances)."""
    import numpy as np

    rng = np.random.default_rng(0)
    cfg, _ = _setup()
    shared = rng.integers(0, cfg.vocab, SHARED_PREFIX_LEN).astype(np.int32)
    tails = [
        rng.integers(0, cfg.vocab, SHARED_TAIL_LEN).astype(np.int32)
        for _ in range(3)
    ]
    _shared_prefix_iter(shared, tails)  # compile warmup (discarded)
    colds, warms, hit_rate = [], [], 0.0
    for _ in range(n_iters):
        cold, warm, eng = _shared_prefix_iter(shared, tails)
        colds.append(cold)
        warms.extend(warm)
        hit_rate = eng.stats.prefix_hit_rate
    cold_ms = min(colds) * 1e3
    warm_ms = min(warms) * 1e3
    return {
        "prompt_len": SHARED_PREFIX_LEN + SHARED_TAIL_LEN,
        "prefix_share": SHARED_PREFIX_LEN / (SHARED_PREFIX_LEN + SHARED_TAIL_LEN),
        "cold_ttft_ms": cold_ms,
        "warm_ttft_ms": warm_ms,
        "ttft_speedup": cold_ms / max(warm_ms, 1e-9),
        "prefix_hit_rate": hit_rate,
    }


def _spec_iter(prompts, spec_k: int, temperature: float = 0.0):
    """One engine pass over the repetition workload; returns (tok/s, accept,
    out_tokens).  spec_k == 0 is the plain control, temperature 0 greedy;
    sampled passes seed request i with i (fixed-seed reproducibility).
    Every prompt gets its own slot (the sampled workload runs wider than
    SPEC_BATCH to amortize fixed per-call host overhead)."""
    from repro.serve import PrecisionParams, SamplingParams, ServeEngine

    cfg, params = _setup()
    page_size = 8
    pages_per_slot = -(-(SPEC_PROMPT_LEN + SPEC_NEW_TOKENS) // page_size)
    engine = ServeEngine(
        cfg, params,
        max_slots=len(prompts),
        num_pages=len(prompts) * pages_per_slot,
        page_size=page_size,
        spec_k=spec_k,
        draft_bits=SPEC_DRAFT_BITS,
    )
    # pre-touch lazy setup so decode_s measures decoding, not quantization
    engine.params_for(SPEC_W_BITS)
    engine.params_for(SPEC_DRAFT_BITS)
    engine.cache_for(8)
    precision = PrecisionParams(w_bits=SPEC_W_BITS, kv_bits=8)
    reqs = [
        engine.submit(
            p,
            SamplingParams(
                temperature=temperature, seed=i,
                max_new_tokens=SPEC_NEW_TOKENS,
            ),
            precision,
        )
        for i, p in enumerate(prompts)
    ]
    engine.run()
    s = engine.stats
    return s.decode_tok_per_s, s.spec_accept_rate, [r.out_tokens for r in reqs]


def spec_decode_stats(n_iters: int = 5) -> dict:
    """Speculative vs plain decode throughput on the synthetic-repetition
    workload (motif-tiled prompts, bf16 target, W8 draft, spec_k=3).

    Alternates spec-on / spec-off passes and takes best-of-N of each (this
    box's walltimes swing several-x run to run; min-of-N per the serving
    bench convention), and asserts nothing itself — run.py --smoke gates
    spec-on >= spec-off at accept >= 0.9."""
    import numpy as np

    rng = np.random.default_rng(0)
    cfg, _ = _setup()
    motif = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    prompts = [
        np.tile(motif, SPEC_PROMPT_LEN // len(motif)) for _ in range(SPEC_BATCH)
    ]
    _spec_iter(prompts, 0)  # compile warmup (discarded)
    _spec_iter(prompts, SPEC_K)
    plain_tps, spec_tps, accept = [], [], 0.0
    spec_out = plain_out = None
    for _ in range(n_iters):
        tps, _, plain_out = _spec_iter(prompts, 0)
        plain_tps.append(tps)
        tps, accept, spec_out = _spec_iter(prompts, SPEC_K)
        spec_tps.append(tps)
    return {
        "spec_k": float(SPEC_K),
        "accept_rate": accept,
        "plain_tok_per_s": max(plain_tps),
        "spec_tok_per_s": max(spec_tps),
        "speedup": max(spec_tps) / max(max(plain_tps), 1e-9),
        "outputs_match": float(spec_out == plain_out),
    }


SAMPLE_TEMPERATURE = 0.8
SAMPLE_BATCH = 8  # wider than SPEC_BATCH: decode-call compute should
# dominate the fixed per-call sampling-array overhead the gate measures


def sampling_stats(n_iters: int = 5) -> dict:
    """The sampled generation workload on the synthetic-repetition prompts:
    greedy (temperature 0) vs temperature-0.8 decode throughput, fixed-seed
    reproducibility, and the spec-on sampled accept rate (speculative
    rejection sampling at bf16 target / W8 draft).

    Alternates greedy / sampled passes and takes best-of-N of each (min-of-N
    per the serving bench convention on this noisy box); asserts nothing
    itself — run.py --smoke gates sampled >= 0.9x greedy tok/s and
    spec-sampled accept >= 0.5."""
    import numpy as np

    rng = np.random.default_rng(0)
    cfg, _ = _setup()
    motif = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    prompts = [
        np.tile(motif, SPEC_PROMPT_LEN // len(motif))
        for _ in range(SAMPLE_BATCH)
    ]
    t = SAMPLE_TEMPERATURE
    _spec_iter(prompts, 0)  # compile warmup (discarded)
    _spec_iter(prompts, 0, temperature=t)
    _spec_iter(prompts, SPEC_K, temperature=t)
    greedy_tps, sampled_tps, ratios = [], [], []
    sampled_out = None
    for _ in range(n_iters):
        g_tps, _, _ = _spec_iter(prompts, 0)
        greedy_tps.append(g_tps)
        s_tps, _, sampled_out = _spec_iter(prompts, 0, temperature=t)
        sampled_tps.append(s_tps)
        # ratio per adjacent pair: the two passes see the same box load, so
        # the best pair isolates sampling overhead from walltime noise
        # (best-of-N convention; a cross-pair max/max ratio mixes phases)
        ratios.append(s_tps / max(g_tps, 1e-9))
    # reproducibility: one more sampled pass must replay the streams exactly
    _, _, replay_out = _spec_iter(prompts, 0, temperature=t)
    _, spec_accept, _ = _spec_iter(prompts, SPEC_K, temperature=t)
    return {
        "temperature": t,
        "greedy_tok_per_s": max(greedy_tps),
        "sampled_tok_per_s": max(sampled_tps),
        "sampled_vs_greedy": max(ratios),
        "seed_reproducible": float(sampled_out == replay_out),
        "spec_sampled_accept": spec_accept,
    }


HEADER = "name,decode_tok_per_s,ttft_ms_p50,tok_ms_p50,tok_ms_p99,occupancy"


def format_row(name: str, r: dict) -> str:
    return (f"{name},{r['decode_tok_per_s']:.1f},{r['ttft_ms_p50']:.1f},"
            f"{r['tok_ms_p50']:.1f},{r['tok_ms_p99']:.1f},{r['occupancy']:.2f}")


if __name__ == "__main__":
    print(HEADER)
    for name, r in rows():
        print(format_row(name, r))
    sp = shared_prefix_stats()
    print("\nname,value")
    for k, v in sp.items():
        print(f"shared_prefix_{k},{v:.3f}")
    for k, v in spec_decode_stats().items():
        print(f"spec_decode_{k},{v:.3f}")
    for k, v in sampling_stats().items():
        print(f"sampling_{k},{v:.3f}")
