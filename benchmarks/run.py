"""Benchmark harness: one section per paper table/figure + kernel microbench
+ the serving-engine latency/throughput sweep + the prefix-cache workload.

Prints ``name,value,paper_value,rel_err`` CSV per reproduction row and
``name,us_per_call,derived`` for the microbenchmarks.  Roofline tables come
from the dry-run artifacts (python -m repro.launch.roofline), not this box's
CPU walltime.

``--smoke`` runs only the kernel microbenchmarks at small shapes plus one
tiny serving row, the shared-prefix cold/warm TTFT row, the
speculative-decoding row, and the sampled-generation row — a CI guard that
the perf plumbing keeps importing, compiling and producing sane numbers
(that a warm prefix cache actually cuts TTFT, that spec-on decode is no
slower than spec-off at >= 0.9 draft acceptance on the synthetic-repetition
workload, and that seeded sampling reproduces its streams, costs < 10% of
greedy throughput, and keeps spec-sampled acceptance >= 0.5); the paper
tables and full sweeps stay out of the hot CI path.  ``--json PATH``
additionally writes the smoke rows as JSON so CI can archive the bench
trajectory per PR (``BENCH_smoke.json`` artifacts).
"""
from __future__ import annotations

import argparse
import json


def smoke(json_path: str | None = None) -> None:
    import math

    from benchmarks import kernel_bench, serve_bench

    artifact: dict[str, float] = {}
    failures: list[str] = []  # gates deferred so the artifact always lands

    print("# === Kernel microbench (smoke shapes) ===")
    print("name,us_per_call,derived")
    rows = kernel_bench.rows(smoke=True)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.2f}")
        artifact[f"{name}_us"] = us
    if not all(math.isfinite(us) and math.isfinite(d) for _, us, d in rows):
        failures.append("non-finite benchmark value")
    if not any(n.startswith("decode_paged") for n, _, _ in rows):
        failures.append("paged decode rows missing from kernel_bench")

    print("\n# === Serving engine (smoke) ===")
    print(serve_bench.HEADER)
    r = serve_bench._run_one(2, [8])
    print(serve_bench.format_row("serve_w8_b2", r))
    artifact.update({f"serve_w8_b2_{k}": v for k, v in r.items()})
    if not r["decode_tok_per_s"] > 0:
        failures.append("serving throughput not positive")

    print("\n# === Prefix cache (shared system prompt, cold vs warm TTFT) ===")
    print("name,value")
    sp = serve_bench.shared_prefix_stats(n_iters=3)
    for k, v in sp.items():
        print(f"shared_prefix_{k},{v:.3f}")
        artifact[f"shared_prefix_{k}"] = v
    if not sp["warm_ttft_ms"] < sp["cold_ttft_ms"]:
        failures.append("warm prefix cache slower than cold prefill")
    if sp["prefix_share"] >= 0.5 and sp["ttft_speedup"] < 2.0:
        failures.append(
            f"warm-vs-cold TTFT speedup {sp['ttft_speedup']:.2f}x "
            f"< 2x at {sp['prefix_share']:.0%} prefix share"
        )
    if sp["prefix_hit_rate"] <= 0:
        failures.append("prefix cache never hit")

    print("\n# === Speculative decoding (synthetic repetition, spec vs plain) ===")
    print("name,value")
    sd = serve_bench.spec_decode_stats(n_iters=5)
    for k, v in sd.items():
        print(f"spec_decode_{k},{v:.3f}")
        artifact[f"spec_decode_{k}"] = v
    if not sd["outputs_match"]:
        failures.append("spec-on output tokens differ from plain greedy")
    if sd["accept_rate"] < 0.9:
        failures.append(
            f"draft accept rate {sd['accept_rate']:.2f} < 0.9 on the "
            "high-accept synthetic-repetition workload"
        )
    elif sd["spec_tok_per_s"] < sd["plain_tok_per_s"]:
        # gated only at high accept: throughput parity is the claim the
        # accept rate earns (min-of-N on a noisy box, see serve_bench)
        failures.append(
            f"spec-on decode {sd['spec_tok_per_s']:.0f} tok/s < spec-off "
            f"{sd['plain_tok_per_s']:.0f} tok/s at accept "
            f"{sd['accept_rate']:.2f}"
        )

    print("\n# === Sampled generation (greedy vs temperature, spec-sampled) ===")
    print("name,value")
    sa = serve_bench.sampling_stats(n_iters=3)
    for k, v in sa.items():
        print(f"sampling_{k},{v:.3f}")
        artifact[f"sampling_{k}"] = v
    if not sa["seed_reproducible"]:
        failures.append("fixed-seed sampled streams not reproducible")
    if sa["sampled_vs_greedy"] < 0.9:
        failures.append(
            f"sampled decode {sa['sampled_tok_per_s']:.0f} tok/s < 0.9x "
            f"greedy {sa['greedy_tok_per_s']:.0f} tok/s (in-jit sampling "
            "should be near-free)"
        )
    if sa["spec_sampled_accept"] < 0.5:
        failures.append(
            f"spec-sampled accept rate {sa['spec_sampled_accept']:.2f} < 0.5 "
            "on the synthetic-repetition workload (W8 draft tracks a bf16 "
            "target closely; rejection sampling should accept most drafts)"
        )

    # write the trajectory BEFORE gating: failing runs are exactly the ones
    # whose numbers the CI artifact exists to preserve
    if json_path:
        with open(json_path, "w") as f:
            json.dump(artifact, f, indent=1, sort_keys=True)
        print(f"\n# wrote {len(artifact)} rows to {json_path}")
    if failures:
        # hard exit, not assert: the guard must survive python -O
        raise SystemExit("smoke: " + "; ".join(failures))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--smoke", action="store_true",
        help="small-shape kernel + serving + prefix-cache smoke run (CI guard)",
    )
    parser.add_argument(
        "--json", default=None, metavar="PATH",
        help="write smoke rows as JSON (bench-trajectory artifact)",
    )
    args = parser.parse_args()
    if args.smoke:
        smoke(args.json)
        return

    from benchmarks import fig3, fig4, kernel_bench, serve_bench, table1

    print("# === Table I (SPEED vs Ara synthesized/peak) ===")
    print("name,model,paper,rel_err")
    for name, got, paper, err in table1.rows():
        print(f"{name},{got:.3f},{paper:.3f},{err * 100:.1f}%")

    print("\n# === Fig. 3 (GoogLeNet layer-wise dataflows, 16-bit) ===")
    print("name,model,paper,rel_err")
    for name, got, paper, err in fig3.rows():
        print(f"{name},{got:.3f},{paper:.3f},{err * 100:.1f}%")
    by_kernel = fig3.compute()["by_kernel"]
    for k, cnt in sorted(by_kernel.items()):
        print(f"fig3_selector_conv{k}x{k},{dict(cnt)}")

    print("\n# === Fig. 4 (avg area efficiency across 4 DNNs) ===")
    print("name,model,paper,rel_err")
    for name, got, paper, err in fig4.rows():
        print(f"{name},{got:.3f},{paper:.3f},{err * 100:.1f}%")

    print("\n# === Kernel microbench (CPU XLA path; TPU perf => roofline) ===")
    print("name,us_per_call,derived")
    for name, us, derived in kernel_bench.rows():
        print(f"{name},{us:.1f},{derived:.2f}")

    print("\n# === Serving engine (continuous batching, by batch & precision mix) ===")
    print(serve_bench.HEADER)
    for name, r in serve_bench.rows():
        print(serve_bench.format_row(name, r))

    print("\n# === Prefix cache (shared system prompt, cold vs warm TTFT) ===")
    print("name,value")
    for k, v in serve_bench.shared_prefix_stats().items():
        print(f"shared_prefix_{k},{v:.3f}")

    print("\n# === Speculative decoding (synthetic repetition, spec vs plain) ===")
    print("name,value")
    for k, v in serve_bench.spec_decode_stats().items():
        print(f"spec_decode_{k},{v:.3f}")


if __name__ == "__main__":
    main()
