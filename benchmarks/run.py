"""Benchmark harness: one section per paper table/figure + kernel microbench
+ the serving-engine throughput sweep.

Prints ``name,value,paper_value,rel_err`` CSV per reproduction row and
``name,us_per_call,derived`` for the microbenchmarks.  Roofline tables come
from the dry-run artifacts (python -m repro.launch.roofline), not this box's
CPU walltime.

``--smoke`` runs only the kernel microbenchmarks at small shapes (plus one
tiny serving row) — a CI guard that the perf plumbing keeps importing,
compiling and producing sane numbers; the paper tables and full sweeps stay
out of the hot CI path.
"""
from __future__ import annotations

import argparse


def smoke() -> None:
    from benchmarks import kernel_bench, serve_bench

    print("# === Kernel microbench (smoke shapes) ===")
    print("name,us_per_call,derived")
    rows = kernel_bench.rows(smoke=True)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.2f}")
    # hard exits, not asserts: the guard must survive python -O
    import math

    if not all(math.isfinite(us) and math.isfinite(d) for _, us, d in rows):
        raise SystemExit("smoke: non-finite benchmark value")
    if not any(n.startswith("decode_paged") for n, _, _ in rows):
        raise SystemExit("smoke: paged decode rows missing from kernel_bench")

    print("\n# === Serving engine (smoke) ===")
    print("name,decode_tok_per_s,mean_batch_occupancy")
    tok_s, occ = serve_bench._run_one(2, [8])
    print(f"serve_w8_b2,{tok_s:.1f},{occ:.2f}")
    if not tok_s > 0:
        raise SystemExit("smoke: serving throughput not positive")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--smoke", action="store_true",
        help="small-shape kernel + serving smoke run (CI guard)",
    )
    args = parser.parse_args()
    if args.smoke:
        smoke()
        return

    from benchmarks import fig3, fig4, kernel_bench, serve_bench, table1

    print("# === Table I (SPEED vs Ara synthesized/peak) ===")
    print("name,model,paper,rel_err")
    for name, got, paper, err in table1.rows():
        print(f"{name},{got:.3f},{paper:.3f},{err * 100:.1f}%")

    print("\n# === Fig. 3 (GoogLeNet layer-wise dataflows, 16-bit) ===")
    print("name,model,paper,rel_err")
    for name, got, paper, err in fig3.rows():
        print(f"{name},{got:.3f},{paper:.3f},{err * 100:.1f}%")
    by_kernel = fig3.compute()["by_kernel"]
    for k, cnt in sorted(by_kernel.items()):
        print(f"fig3_selector_conv{k}x{k},{dict(cnt)}")

    print("\n# === Fig. 4 (avg area efficiency across 4 DNNs) ===")
    print("name,model,paper,rel_err")
    for name, got, paper, err in fig4.rows():
        print(f"{name},{got:.3f},{paper:.3f},{err * 100:.1f}%")

    print("\n# === Kernel microbench (CPU XLA path; TPU perf => roofline) ===")
    print("name,us_per_call,derived")
    for name, us, derived in kernel_bench.rows():
        print(f"{name},{us:.1f},{derived:.2f}")

    print("\n# === Serving engine (continuous batching, tokens/s by batch & precision mix) ===")
    print("name,decode_tok_per_s,mean_batch_occupancy")
    for name, tok_s, occ in serve_bench.rows():
        print(f"{name},{tok_s:.1f},{occ:.2f}")


if __name__ == "__main__":
    main()
