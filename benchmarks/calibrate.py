"""Calibrates the free microarchitectural parameters of core/perfmodel.py
against the paper's own reported numbers (Table I + Fig. 3 + Fig. 4).

The synthesized constants (area, power, frequency) are taken from Table I as
given; ONLY the dataflow/bandwidth/overhead parameters are fitted, and the
qualitative behaviours (CF wins 1x1 / FF wins K>=3 / 4-bit ~3x 8-bit) must
emerge from the model, not be coded in.  Run:

    PYTHONPATH=src python -m benchmarks.calibrate [--iters 4000]

Prints the best-fit parameters (to be frozen into perfmodel defaults) and the
per-target relative errors.
"""
from __future__ import annotations

import argparse
import math
import random

from repro.core.perfmodel import (
    AraModel,
    SpeedModel,
    evaluate_network,
    evaluate_network_ara,
)
from repro.core.precision import Precision
from repro.models.cnn_zoo import BENCHMARK_NETWORKS, googlenet_layers

I16, I8, I4 = Precision.INT16, Precision.INT8, Precision.INT4

SPEED_SPACE = {
    "ext_bw_bits": (16.0, 512.0),
    "vrf_bw_values": (2.0, 160.0),
    "out_bw_values": (2.0, 64.0),
    "chain_bubble": (0.0, 8.0),
    "issue_cycles": (0.0, 96.0),
    "overlap": (0.30, 0.98),
    "sau_eff": (0.35, 1.0),
    "vrf_read_bits": (64.0, 2048.0),
    "layer_startup": (0.0, 30000.0),
    "col_drain": (0.0, 16.0),
}
ARA_SPACE = {
    "ext_bw_bits": (16.0, 512.0),
    "slide_penalty": (1.0, 6.0),
    "issue_cycles": (0.0, 96.0),
    "overlap": (0.10, 0.95),
    "w16_penalty": (1.0, 3.0),
    "layer_startup": (0.0, 30000.0),
}


def _all_layers():
    return [l for f in BENCHMARK_NETWORKS.values() for l in f()]


def evaluate_models(sm: SpeedModel, am: AraModel) -> dict[str, float]:
    """Computes every quantity the paper reports that we calibrate against."""
    nets = {k: f() for k, f in BENCHMARK_NETWORKS.items()}
    out: dict[str, float] = {}
    # Table I peaks: best per-layer throughput across all benchmark convs.
    from repro.core.isa import Dataflow

    layers = _all_layers()
    for prec, key in [(I16, "peak16"), (I8, "peak8"), (I4, "peak4")]:
        out[key] = max(
            max(
                sm.evaluate(l, prec, Dataflow.FF).gops,
                sm.evaluate(l, prec, Dataflow.CF).gops,
            )
            for l in layers
        )
    for prec, key in [(I16, "ara_peak16"), (I8, "ara_peak8")]:
        out[key] = max(am.evaluate(l, prec).gops for l in layers)
    # Fig. 3: GoogLeNet @16-bit, strategy comparison (network-level).
    gl = googlenet_layers()
    g_ff = evaluate_network(gl, I16, "ff", sm)["area_eff"]
    g_cf = evaluate_network(gl, I16, "cf", sm)["area_eff"]
    g_mx = evaluate_network(gl, I16, "mixed", sm)["area_eff"]
    g_ara = evaluate_network_ara(gl, I16, am)["area_eff"]
    out["fig3_mx_over_ff"] = g_mx / g_ff
    out["fig3_mx_over_cf"] = g_mx / g_cf
    out["fig3_ff_over_ara"] = g_ff / g_ara
    out["fig3_cf_over_ara"] = g_cf / g_ara
    out["fig3_mx_over_ara"] = g_mx / g_ara
    # Fig. 4: averages over the four networks (mixed strategy).
    for prec, key in [(I16, "avg16"), (I8, "avg8"), (I4, "avg4")]:
        vals = [evaluate_network(ls, prec, "mixed", sm)["area_eff"] for ls in nets.values()]
        out[key] = sum(vals) / len(vals)
    for prec, key in [(I16, "ara_avg16"), (I8, "ara_avg8")]:
        vals = [evaluate_network_ara(ls, prec, am)["area_eff"] for ls in nets.values()]
        out[key] = sum(vals) / len(vals)
    out["fig4_ratio16"] = out["avg16"] / out["ara_avg16"]
    out["fig4_ratio8"] = out["avg8"] / out["ara_avg8"]
    return out


# (target value, weight) — throughputs in GOPS, efficiencies in GOPS/mm^2.
TARGETS: dict[str, tuple[float, float]] = {
    "peak16": (34.89, 3.0),
    "peak8": (93.65, 3.0),
    "peak4": (287.41, 3.0),
    "ara_peak16": (6.82, 3.0),
    "ara_peak8": (22.95, 3.0),
    "fig3_mx_over_ff": (1.88, 2.0),
    "fig3_mx_over_cf": (1.38, 4.0),
    "fig3_ff_over_ara": (1.87, 0.5),
    "fig3_cf_over_ara": (2.55, 0.5),
    "fig3_mx_over_ara": (3.53, 2.0),
    "fig4_ratio16": (2.77, 2.0),
    "fig4_ratio8": (6.39, 4.0),
    "avg4": (94.6, 2.0),
}


def loss(metrics: dict[str, float]) -> float:
    tot = 0.0
    for k, (tgt, w) in TARGETS.items():
        m = metrics.get(k, 1e-9)
        if m <= 0 or not math.isfinite(m):
            return float("inf")
        tot += w * math.log(m / tgt) ** 2
    return tot


def _sample(space: dict, rng: random.Random, center: dict | None = None, width: float = 1.0) -> dict:
    p = {}
    for k, (lo, hi) in space.items():
        if center is None or width >= 1.0:
            p[k] = rng.uniform(lo, hi)
        else:
            span = (hi - lo) * width
            c = center[k]
            p[k] = min(hi, max(lo, rng.uniform(c - span, c + span)))
    return p


def fit(iters: int = 4000, seed: int = 0) -> tuple[dict, dict, dict]:
    rng = random.Random(seed)
    best = (float("inf"), None, None)
    center_s = center_a = None
    # annealed random search: global -> progressively local
    schedule_w = [(0.30, 1.0), (0.30, 0.3), (0.25, 0.1), (0.15, 0.03)]
    bounds = []
    acc = 0.0
    for frac, w in schedule_w:
        acc += frac
        bounds.append((acc, w))
    for i in range(iters):
        f = i / iters
        width = next(w for b, w in bounds if f <= b)
        if best[1] is None:
            width = 1.0
        ps = _sample(SPEED_SPACE, rng, center_s, width)
        pa = _sample(ARA_SPACE, rng, center_a, width)
        sm = SpeedModel(**ps)
        am = AraModel(**pa)
        try:
            m = evaluate_models(sm, am)
        except (ValueError, ZeroDivisionError):
            continue
        l = loss(m)
        if l < best[0]:
            best = (l, ps, pa)
            center_s, center_a = ps, pa
    return best  # type: ignore[return-value]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=4000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--restarts", type=int, default=1)
    args = ap.parse_args()
    l, ps, pa = min(
        (fit(args.iters, args.seed + r) for r in range(args.restarts)),
        key=lambda t: t[0],
    )
    print(f"best loss {l:.4f}")
    print("SpeedModel params:", {k: round(v, 3) for k, v in ps.items()})
    print("AraModel params:", {k: round(v, 3) for k, v in pa.items()})
    m = evaluate_models(SpeedModel(**ps), AraModel(**pa))
    print(f"{'metric':<18}{'model':>10}{'paper':>10}{'rel_err':>9}")
    for k, (tgt, _) in TARGETS.items():
        print(f"{k:<18}{m[k]:>10.2f}{tgt:>10.2f}{(m[k]/tgt - 1)*100:>8.1f}%")


if __name__ == "__main__":
    main()
