"""Table I reproduction: SPEED vs Ara synthesized/peak metrics.

Peak = best conv layer across all four DNN benchmarks (the paper: "through
evaluating each convolutional layer in all DNN benchmarks")."""
from __future__ import annotations

from repro.core.isa import Dataflow
from repro.core.perfmodel import AraModel, SpeedModel
from repro.core.precision import Precision
from repro.models.cnn_zoo import BENCHMARK_NETWORKS

PAPER = {  # (speed, ara) per metric/precision — Table I
    ("throughput", 16): (34.89, 6.82),
    ("throughput", 8): (93.65, 22.95),
    ("throughput", 4): (287.41, None),
    ("area_eff", 16): (31.72, 15.51),
    ("area_eff", 8): (85.13, 52.16),
    ("area_eff", 4): (261.28, None),
    ("energy_eff", 16): (162.15, 111.61),
    ("energy_eff", 8): (435.25, 373.68),
    ("energy_eff", 4): (1335.79, None),
}


def compute(sm: SpeedModel | None = None, am: AraModel | None = None) -> dict:
    sm, am = sm or SpeedModel(), am or AraModel()
    layers = [l for f in BENCHMARK_NETWORKS.values() for l in f()]
    out = {}
    for bits in (16, 8, 4):
        prec = Precision.from_bits(bits)
        speed_peak = max(
            max(
                sm.evaluate(l, prec, Dataflow.FF).gops,
                sm.evaluate(l, prec, Dataflow.CF).gops,
            )
            for l in layers
        )
        ara_peak = (
            max(am.evaluate(l, prec).gops for l in layers) if bits != 4 else None
        )
        out[("throughput", bits)] = (speed_peak, ara_peak)
        out[("area_eff", bits)] = (
            speed_peak / sm.area_mm2,
            ara_peak / am.area_mm2 if ara_peak else None,
        )
        out[("energy_eff", bits)] = (
            speed_peak / sm.power_w,
            ara_peak / am.power_w if ara_peak else None,
        )
    return out


def rows() -> list[tuple]:
    got = compute()
    out = []
    for key, (p_s, p_a) in PAPER.items():
        g_s, g_a = got[key]
        out.append((f"table1_{key[0]}_{key[1]}b_speed", g_s, p_s, g_s / p_s - 1))
        if p_a is not None and g_a is not None:
            out.append((f"table1_{key[0]}_{key[1]}b_ara", g_a, p_a, g_a / p_a - 1))
    # headline derived ratios the abstract quotes
    s16, a16 = got[("area_eff", 16)]
    s8, a8 = got[("area_eff", 8)]
    out.append(("table1_area_ratio_16b", s16 / a16, 2.04, s16 / a16 / 2.04 - 1))
    out.append(("table1_area_ratio_8b", s8 / a8, 1.63, s8 / a8 / 1.63 - 1))
    return out


def main() -> None:
    print(f"{'metric':<34}{'model':>10}{'paper':>10}{'rel_err':>9}")
    for name, got, paper, err in rows():
        print(f"{name:<34}{got:>10.2f}{paper:>10.2f}{err * 100:>8.1f}%")


if __name__ == "__main__":
    main()
